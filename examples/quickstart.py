"""Quickstart: explore a design space over a real JAX workload in-process.

    PYTHONPATH=src python examples/quickstart.py

This is the 60-second version of the paper's Algorithm 1: a JHost drives two
JClients (threads here; separate hosts on a real fleet) that compile a small
llama-family model once per software-knob variant and evaluate the hardware
ladders analytically — then prints the Pareto frontier.
"""
import os
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_arch, reduced
from repro.configs.base import ShapeConfig
from repro.core import (JClient, JConfig, JHost, RandomSearch, ResultStore,
                        transport)
from repro.core.space import DesignSpace, Knob, KIND_HW, KIND_SW
from repro.launch.build import build_generation
from repro.launch.mesh import make_host_mesh
from repro.roofline.analysis import summarize
from repro.roofline.hw import CLOCK_LADDER, HBM_LADDER, ICI_LADDER
from repro.roofline.traffic import analytic_hbm_bytes_per_device

# 1. the design space — Table I of the TPU adaptation
space = DesignSpace([
    Knob("clock_scale", CLOCK_LADDER, KIND_HW),   # GPU-freq analogue
    Knob("hbm_scale", HBM_LADDER, KIND_HW),       # EMC-freq analogue
    Knob("ici_scale", ICI_LADDER, KIND_HW),
    Knob("attn_block_q", (16, 32), KIND_SW),      # kernel tiling (recompiles)
])
jc = JConfig(space, n_chips=1)

# 2. the workload — anything; here: greedy generation with a reduced llama2
arch = reduced(get_arch("llama2-7b"))
mesh = make_host_mesh()


def build(tc):
    flags = jc.build_flags(tc.knobs)
    pre_cell, dec_cell = build_generation(arch, mesh, flags, batch=1,
                                          prompt_len=16, max_len=48)
    pre, dec = summarize(pre_cell.compiled, 1), summarize(dec_cell.compiled, 1)
    pre.hbm_est_per_device = analytic_hbm_bytes_per_device(
        arch, ShapeConfig("p", "prefill", 16, 1), flags, 1, 1, 1)
    dec.hbm_est_per_device = analytic_hbm_bytes_per_device(
        arch, ShapeConfig("d", "decode", 48, 1), flags, 1, 1, 1)
    return pre, {"decode_artifact": dec, "n_decode_tokens": 32}


# 3. boards (threads here, ZMQ hosts on a fleet) + host + search algorithm
pair = transport.LoopbackPair(2)
for i in range(2):
    c = JClient(jc, build, transport=pair.client(i), client_id=i)
    threading.Thread(target=c.serve, kwargs=dict(poll_s=0.02,
                                                 idle_limit_s=None),
                     daemon=True).start()

host = JHost(pair.host(), ResultStore(), timeout_s=300)
host.explore(RandomSearch(space, seed=0), arch.name, "generate", 40)

# 4. results
front = host.store.pareto_front(["time_s", "power_w"])
print(f"\nexplored 40 configs; pareto frontier ({len(front)} points):")
for r in sorted(front, key=lambda r: r.metrics["time_s"]):
    print(f"  time {r.metrics['time_s']*1e3:8.3f} ms   power {r.metrics['power_w']:5.1f} W"
          f"   clock={r.knobs['clock_scale']:<5} hbm={r.knobs['hbm_scale']:.3f}"
          f" ici={r.knobs['ici_scale']:.2f}")
host.stop_clients()
