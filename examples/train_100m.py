"""End-to-end training driver: ~100M-param llama-family model, few hundred
steps, with checkpointing — deliverable (b)'s end-to-end example.

    PYTHONPATH=src python examples/train_100m.py [--steps 200] [--tiny]

--tiny shrinks the model for quick demonstration on one CPU core; the default
config is ~100M params (the full run takes a few hours on CPU, minutes on any
accelerator).
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLM, device_put_batch
from repro.models import BuildFlags, Model
from repro.train import (CheckpointManager, TrainStepConfig, adamw,
                         cosine_schedule, init_train_state, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    base = get_arch("tinyllama-1.1b")
    if args.tiny:
        arch = dataclasses.replace(base, name="llama-6m", n_layers=4,
                                   d_model=128, n_heads=4, n_kv_heads=2,
                                   head_dim=32, d_ff=512, vocab_size=4096)
    else:
        arch = dataclasses.replace(base, name="llama-100m", n_layers=10,
                                   d_model=640, n_heads=10, n_kv_heads=2,
                                   head_dim=64, d_ff=1792, vocab_size=32000)
    model = Model(arch, BuildFlags(dtype="float32", remat="selective", sp=False))
    print(f"model: {arch.name}  params ≈ {arch.param_count()/1e6:.1f}M")

    opt = adamw(cosine_schedule(3e-4, args.steps // 10, args.steps))
    tsc = TrainStepConfig(microbatch=1)
    state = init_train_state(model, opt, jax.random.key(0), tsc)
    step_fn = jax.jit(make_train_step(model, opt, tsc), donate_argnums=(0,))

    ck = CheckpointManager(args.ckpt, keep=2)
    start = ck.latest_step() or 0
    if start:
        state = ck.restore(start, jax.eval_shape(lambda: state))
        print(f"resumed from step {start}")

    data = SyntheticLM(arch, DataConfig(args.batch, args.seq, seed=0))
    t0 = time.time()
    for step in range(start, args.steps):
        state, m = step_fn(state, device_put_batch(data.batch(step)))
        if (step + 1) % 10 == 0:
            dt = (time.time() - t0) / (step - start + 1)
            tok_s = args.batch * args.seq / dt
            print(f"step {step+1:4d}  loss {float(m['loss']):.4f}  "
                  f"{dt*1e3:6.0f} ms/step  {tok_s:7.0f} tok/s", flush=True)
        if (step + 1) % 50 == 0:
            ck.save(step + 1, state)
    ck.save(args.steps, state, block=True)
    print("done; final checkpoint at", args.ckpt)


if __name__ == "__main__":
    main()
