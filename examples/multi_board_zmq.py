"""Multi-board exploration over ZMQ — the paper's deployment shape.

    PYTHONPATH=src python examples/multi_board_zmq.py

Spawns two client *processes* (stand-ins for two Jetson boards / TPU slices),
each binding a ZMQ PULL socket for configs and PUSHing results back to the
host — the exact socket roles of paper §III.  The host runs NSGA-II and
re-queues work if a board dies (kill a client mid-run to watch).
"""
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

CLIENT_CODE_TEMPLATE = """
import sys
sys.path.insert(0, SRC_PATH)
from repro.core import JClient, JConfig, tpu_pod_space, transport
from repro.roofline.analysis import Artifact

cid, cfg_port, res_port = int(sys.argv[1]), sys.argv[2], sys.argv[3]
space = tpu_pod_space(n_chips=64)
jc = JConfig(space, n_chips=64)

def build(tc):
    # stand-in workload (a real board would compile the model here)
    import hashlib
    h = int(hashlib.md5(str(sorted(tc.knobs.items())).encode()).hexdigest(), 16)
    return Artifact(flops_per_device=4e12 + (h % 7) * 1e11,
                    bytes_per_device=2e10, wire_bytes_per_device=2e8,
                    collectives={}, arg_bytes=10**9, temp_bytes=10**8,
                    output_bytes=10**6, n_devices=64), {}

t = transport.ZmqClientTransport(f"tcp://127.0.0.1:{cfg_port}",
                                 f"tcp://127.0.0.1:{res_port}")
served = JClient(jc, build, transport=t, client_id=cid).serve(poll_s=0.2,
                                                              idle_limit_s=30)
print(f"[board {cid}] served {served} configs", flush=True)
"""
CLIENT_CODE = ("SRC_PATH = %r\n" % os.path.abspath(SRC)) + CLIENT_CODE_TEMPLATE


def main():
    from repro.core import (JHost, NSGA2, ResultStore, tpu_pod_space,
                            transport)

    cfg_ports, res_port = [15701, 15702], 15700
    procs = [subprocess.Popen([sys.executable, "-c", CLIENT_CODE,
                               str(i), str(cfg_ports[i]), str(res_port)])
             for i in range(2)]
    time.sleep(1.0)  # let boards bind

    host_t = transport.ZmqHostTransport(
        f"tcp://*:{res_port}",
        {i: f"tcp://127.0.0.1:{cfg_ports[i]}" for i in range(2)})
    space = tpu_pod_space(n_chips=64)
    host = JHost(host_t, ResultStore(), timeout_s=20.0)
    host.explore(NSGA2(space, seed=0, pop_size=12), "toy", "train_4k", 48,
                 progress=True)
    host.stop_clients()

    front = host.store.pareto_front(["time_s", "power_w"])
    by_client = {}
    for r in host.store.ok_records():
        by_client[r.client_id] = by_client.get(r.client_id, 0) + 1
    print(f"explored 48 configs across boards {by_client}; "
          f"pareto front = {len(front)} points")
    for p in procs:
        p.wait(timeout=40)


if __name__ == "__main__":
    main()
