"""Multi-board exploration over ZMQ — the paper's deployment shape.

    PYTHONPATH=src python examples/multi_board_zmq.py

Spawns two client *processes* (stand-ins for two Jetson boards / TPU slices),
each binding a ZMQ PULL socket for configs and PUSHing results back to the
host — the exact socket roles of paper §III.  The host runs NSGA-II through
the **pipelined dispatch scheduler**: chunks of configs travel as single
columnar frames in the compact binary codec, every board's queue is kept two
chunks deep (no idle gap between a board's result push and its next pull),
and chunk sizes adapt to each board's observed per-config wall time.  Work
is re-queued if a board dies (kill a client mid-run to watch).  Each board
reports its artifact-cache counters (``cache_info``) on exit.
"""
import os
import subprocess
import sys
import time

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

CLIENT_CODE_TEMPLATE = """
import sys
sys.path.insert(0, SRC_PATH)
from repro.core import JClient, JConfig, tpu_pod_space, transport
from repro.roofline.analysis import Artifact

cid, cfg_port, res_port = int(sys.argv[1]), sys.argv[2], sys.argv[3]
space = tpu_pod_space(n_chips=64)
jc = JConfig(space, n_chips=64)

def build(tc):
    # stand-in workload (a real board would compile the model here)
    import hashlib
    h = int(hashlib.md5(str(sorted(tc.knobs.items())).encode()).hexdigest(), 16)
    return Artifact(flops_per_device=4e12 + (h % 7) * 1e11,
                    bytes_per_device=2e10, wire_bytes_per_device=2e8,
                    collectives={}, arg_bytes=10**9, temp_bytes=10**8,
                    output_bytes=10**6, n_devices=64), {}

# the client stays codec-agnostic: it sniffs the host's frames and answers
# in the same codec (binary here, since the host speaks binary)
t = transport.ZmqClientTransport(f"tcp://127.0.0.1:{cfg_port}",
                                 f"tcp://127.0.0.1:{res_port}")
client = JClient(jc, build, transport=t, client_id=cid)
served = client.serve(poll_s=0.2, idle_limit_s=30)
info = client.cache_info()
print(f"[board {cid}] served {served} configs, compiled {client.n_compiled}; "
      f"cache_info: hits={info['hits']} misses={info['misses']} "
      f"evictions={info['evictions']} currsize={info['currsize']}", flush=True)
t.close()
t.close()   # close is idempotent — double-close in teardown paths is safe
"""
CLIENT_CODE = ("SRC_PATH = %r\n" % os.path.abspath(SRC)) + CLIENT_CODE_TEMPLATE


def main():
    from repro.core import (JHost, NSGA2, ResultStore, tpu_pod_space,
                            transport)

    cfg_ports, res_port = [15701, 15702], 15700
    procs = [subprocess.Popen([sys.executable, "-c", CLIENT_CODE,
                               str(i), str(cfg_ports[i]), str(res_port)])
             for i in range(2)]
    time.sleep(1.0)  # let boards bind

    host_t = transport.ZmqHostTransport(
        f"tcp://*:{res_port}",
        {i: f"tcp://127.0.0.1:{cfg_ports[i]}" for i in range(2)},
        codec="binary")
    space = tpu_pod_space(n_chips=64)
    host = JHost(host_t, ResultStore(), timeout_s=20.0)
    t0 = time.time()
    host.explore(NSGA2(space, seed=0, pop_size=12), "toy", "train_4k", 48,
                 progress=True, batch_size=6, dispatch="pipelined",
                 chunk_budget_ms=250.0)
    wall = time.time() - t0
    host.stop_clients()

    front = host.store.pareto_front(["time_s", "power_w"])
    by_client = {}
    for r in host.store.ok_records():
        by_client[r.client_id] = by_client.get(r.client_id, 0) + 1
    stats = host.scheduler.stats()
    print(f"explored 48 configs in {wall:.2f}s across boards {by_client}; "
          f"pareto front = {len(front)} points; "
          f"{stats['chunks_dispatched']:.0f} chunks "
          f"(mean size {stats['mean_chunk']:.1f}, pipelined+binary)")
    if "wire_per_client" in stats:        # per-board codec/bytes-on-wire
        for cid, w in sorted(stats["wire_per_client"].items()):
            print(f"  board {cid}: {w['out_kb']:.1f} KB out in "
                  f"{w['out_frames']} frames, {w['in_kb']:.1f} KB back in "
                  f"{w['in_frames']} frames ({stats.get('codec', '?')})")
    for p in procs:
        p.wait(timeout=40)
    host_t.close()


if __name__ == "__main__":
    main()
