"""Batched greedy serving across architectures (incl. the SSM family).

    PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import BuildFlags, Model
from repro.serve import Engine

for name in ("tinyllama-1.1b", "mamba2-780m", "deepseek-moe-16b"):
    arch = reduced(get_arch(name))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    params = model.init(jax.random.key(0))
    eng = Engine(model, params, max_len=64, donate=False)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, arch.vocab_size, (4, 12)), jnp.int32)}
    t0 = time.time()
    res = eng.generate(batch, 24)
    dt = time.time() - t0
    print(f"{name:<22s} batch=4 prompt=12 gen=24  {dt:5.2f}s "
          f"({4*24/dt:6.1f} tok/s)  first: {res.tokens[0][:8].tolist()}")
