"""SearchDriver — overlap search math with client-side evaluation.

After the batched/pipelined host work, the transport side of the DSE loop
sustains tens of thousands of evals/sec — but a model-based searcher
(BayesOpt/PAL) runs its GP algebra *inline* in ``JHost.explore``, so every
ask stalls the whole fleet.  ``SearchDriver`` wraps any ``SearchAlgorithm``
and moves that math off the host's critical path:

* ``mode="sync"`` — pure pass-through.  Every ``ask``/``tell`` runs inline
  on the caller's thread; picks are bit-identical to the bare algorithm
  (this is the equivalence baseline, and the safe default for cheap
  searchers like random/grid where a worker thread buys nothing).
* ``mode="async"`` — a background worker precomputes asks into a buffer
  while clients evaluate the current chunks.  ``tell``s are buffered and
  folded into the algorithm at ask boundaries — stale-tolerant by design: a
  precomputed pick may lag the newest few observations, exactly like a
  pipelined chunk that was dispatched before its predecessor's results
  landed.  ``max_stale_tells`` bounds that tolerance: a buffered pick that
  would lag the model by more than that many folded tells is discarded and
  recomputed (counted in ``stats()["stale_dropped"]``) instead of being
  handed out.  The host's side of the contract is ``poll_ask``: non-blocking
  whenever evaluation work is in flight (``DispatchScheduler.busy()``), and
  blocking only when the loop cannot otherwise make progress.  The
  scheduler's ``want(lookahead=...)`` is the matching backpressure signal —
  it sizes the precompute buffer so a freed client slot tops up from
  already-computed picks instead of waiting on GP math.

The wrapped algorithm is only ever touched by one thread at a time: in sync
mode the caller's, in async mode the worker's (the host thread just moves
dicts in and out of the buffers under the driver lock).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.search.base import SearchAlgorithm

MODES = ("sync", "async")


class SearchDriver:
    """Plug-in wrapper: speaks ask/tell plus the host's non-blocking hooks."""

    def __init__(self, algo: SearchAlgorithm, mode: str = "async",
                 round_size: int = 32,
                 max_stale_tells: Optional[int] = None):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        if max_stale_tells is not None and max_stale_tells < 0:
            raise ValueError(f"max_stale_tells must be >= 0, "
                             f"got {max_stale_tells!r}")
        self.algo = algo
        self.mode = mode
        self.round_size = max(int(round_size), 1)
        # staleness bound: a buffered pick was computed against the model
        # state at some tell count; once the model has folded more than
        # ``max_stale_tells`` newer observations, the stale buffer is
        # discarded and recomputed instead of being handed out (None keeps
        # the unbounded stale-tolerant behaviour)
        self.max_stale_tells = max_stale_tells
        # buffer entries are (pick, fold-count when the pick was computed),
        # so staleness is judged per pick, not per buffer generation
        self._buf: Deque[Tuple[Dict, int]] = deque()
        self._tells: Deque[Tuple[Dict, np.ndarray]] = deque()
        self._target = 0
        self._closing = False
        self._err: Optional[BaseException] = None
        self._cond = threading.Condition()
        self.n_rounds = 0          # worker ask rounds computed
        self.n_precomputed = 0     # configs ever placed in the buffer
        self.n_tells_folded = 0    # buffered tells folded into the algo
        self.n_stale_dropped = 0   # precomputed picks discarded as too stale
        # residency updates are buffered like tells: the worker owns the
        # algorithm, so the host thread never touches it directly (latest
        # update wins — residency is a snapshot, not a log)
        self._pending_fp_fn: Optional[Tuple] = None
        self._pending_residency: Optional[frozenset] = None
        self._worker: Optional[threading.Thread] = None
        if mode == "async":
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="search-driver")
            self._worker.start()

    # -- SearchAlgorithm protocol ---------------------------------------------
    def ask(self, n: int) -> List[Dict]:
        """Blocking ask: exactly n picks (drop-in for a bare algorithm)."""
        if self.mode == "sync":
            return self.algo.ask(n)
        out: List[Dict] = []
        while len(out) < n:
            out.extend(self.poll_ask(n - len(out), need=True))
        return out

    def tell(self, knobs: Dict, y: np.ndarray) -> None:
        if self.mode == "sync":
            self.algo.tell(knobs, y)
            return
        with self._cond:
            self._tells.append((dict(knobs), np.asarray(y, float)))
            self._cond.notify_all()

    # -- host-facing async hooks ----------------------------------------------
    def poll_ask(self, n: int, need: bool = False) -> List[Dict]:
        """Up to n precomputed picks, possibly none.

        Blocks only when ``need`` is set (the host has nothing in flight and
        cannot make progress without fresh configs); otherwise returns
        whatever the worker has buffered and lets the host go back to
        pulling results while the next ask computes.
        """
        if self.mode == "sync":
            return self.algo.ask(n)
        with self._cond:
            self._target = max(self._target, n)
            self._cond.notify_all()            # demand may wake the worker
            while need and not self._buf and self._err is None \
                    and not self._closing:
                self._cond.wait()
            if self._err is not None:
                raise RuntimeError("search worker died") from self._err
            out = [self._buf.popleft()[0]
                   for _ in range(min(n, len(self._buf)))]
            if out:
                self._cond.notify_all()        # buffer has room: refill
            return out

    def set_sw_fingerprint_fn(self, fn) -> None:
        """Forward the knobs→sw-fingerprint map to the wrapped algorithm
        (inline in sync mode; via the worker in async mode)."""
        if self.mode == "sync":
            if hasattr(self.algo, "set_sw_fingerprint_fn"):
                self.algo.set_sw_fingerprint_fn(fn)
            return
        with self._cond:
            self._pending_fp_fn = (fn,)
            self._cond.notify_all()

    def note_residency(self, fps) -> None:
        """Forward the fleet's resident-fingerprint snapshot (latest wins)."""
        if self.mode == "sync":
            if hasattr(self.algo, "note_residency"):
                self.algo.note_residency(fps)
            return
        with self._cond:
            self._pending_residency = frozenset(fps)
            self._cond.notify_all()

    def note_demand(self, n: int) -> None:
        """Backpressure from the scheduler: keep ~n picks precomputed."""
        if self.mode == "sync":
            return
        with self._cond:
            self._target = max(int(n), 1)
            self._cond.notify_all()

    def ready(self) -> int:
        """Precomputed picks available without blocking."""
        if self.mode == "sync":
            return 0
        with self._cond:
            return len(self._buf)

    # -- lifecycle ------------------------------------------------------------
    def close(self, timeout_s: float = 10.0) -> None:
        if self._worker is None:
            return
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        self._worker.join(timeout=timeout_s)

    def __enter__(self) -> "SearchDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> Dict[str, float]:
        with self._cond:
            return {"mode": self.mode, "buffered": len(self._buf),
                    "pending_tells": len(self._tells),
                    "rounds": self.n_rounds,
                    "precomputed": self.n_precomputed,
                    "tells_folded": self.n_tells_folded,
                    "stale_dropped": self.n_stale_dropped}

    # -- worker ---------------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._cond:
                while (not self._closing and not self._tells
                       and len(self._buf) >= max(self._target, 1)):
                    self._cond.wait()
                if self._closing:
                    return
                tells = list(self._tells)
                self._tells.clear()
                fp_fn, self._pending_fp_fn = self._pending_fp_fn, None
                residency, self._pending_residency = \
                    self._pending_residency, None
                if self.max_stale_tells is not None and self._buf:
                    # discard (oldest-first: bases are monotone) only the
                    # picks that will lag the model by more than the bound
                    # once this round folds; this round recomputes them
                    # against fresh state
                    folded = self.n_tells_folded + len(tells)
                    while self._buf and (folded - self._buf[0][1]
                                         > self.max_stale_tells):
                        self._buf.popleft()
                        self.n_stale_dropped += 1
                want = max(self._target, 1) - len(self._buf)
                # empty buffer means the host may be blocked on us: compute
                # a small round first to unblock it, then get ahead with
                # full rounds while it dispatches
                cap = self.round_size if self._buf else max(
                    min(8, self.round_size), 1)
            try:
                # fold buffered observations at the ask boundary, then
                # precompute the next round while clients keep evaluating
                if fp_fn is not None and \
                        hasattr(self.algo, "set_sw_fingerprint_fn"):
                    self.algo.set_sw_fingerprint_fn(fp_fn[0])
                if residency is not None and \
                        hasattr(self.algo, "note_residency"):
                    self.algo.note_residency(residency)
                for knobs, y in tells:
                    self.algo.tell(knobs, y)
                picks = self.algo.ask(min(want, cap)) if want > 0 else []
            except BaseException as e:        # surface in the host thread
                with self._cond:
                    self._err = e
                    self._cond.notify_all()
                return
            with self._cond:
                self.n_tells_folded += len(tells)
                if picks:
                    self.n_rounds += 1
                    self.n_precomputed += len(picks)
                    self._buf.extend((p, self.n_tells_folded) for p in picks)
                self._cond.notify_all()
