"""Random sampling — the paper's own experiment method ("we randomly sampled
200 Nvidia Jetson Orin configurations")."""
from __future__ import annotations

from typing import Dict, List

from repro.core.search.base import SearchAlgorithm


class RandomSearch(SearchAlgorithm):
    def __init__(self, space, seed: int = 0, dedupe: bool = True,
                 max_tries: int = 50):
        super().__init__(space, seed)
        self.dedupe = dedupe
        self.max_tries = max_tries
        self._seen = set()

    def ask(self, n: int) -> List[Dict]:
        out = []
        for _ in range(n):
            cfg = self.space.sample(self.rng)
            if self.dedupe:
                for _ in range(self.max_tries):
                    if self._key(cfg) not in self._seen:
                        break
                    cfg = self.space.sample(self.rng)
                self._seen.add(self._key(cfg))
            out.append(cfg)
        return out
