"""JAX-backed incremental GP — the device fast path behind ``gp_mode="jax"``.

``JaxIncrementalGP`` mirrors the numpy ``IncrementalGP`` contract
(observe / fit_x / fit_y / predict / the ``_multi`` family) but keeps the
kernel state on the accelerator as fixed-capacity, zero-padded device
buffers and runs every hot step as one jitted call:

* **Rank-append Cholesky on device** — ``observe`` pads the new block to a
  power-of-two width and calls a single donated jit (``_append_jit``) that
  writes X, extends L with ``[[L, 0], [wᵀ, chol(K₂₂ − wᵀw)]]`` and L⁻¹ with
  the matching block inverse.  Buffers double amortizedly exactly like the
  numpy layout, so jit retraces happen per *capacity*, not per call.  The
  padding rows get an identity diagonal inside the jit (the Cholesky of a
  block-diag ``[[K, 0], [0, I]]`` is ``[[L, 0], [0, I]]``) and are re-masked
  to zero afterwards, keeping the invariant every other kernel GEMM relies
  on: rows/cols at index ≥ n are exactly zero.
* **Fused pool scoring** — ``predict_multi`` / ``predict_mean_multi`` /
  ``score_ehvi`` each run kernel GEMM + solve (+ the EHVI staircase sweep)
  over the whole candidate pool in one device call; pools are row-padded to
  powers of two so retraces stay bounded.
* **Inducing points (subset-of-data)** — every observation lands in a
  host-side archive, but past ``inducing_threshold`` active points the
  factor is periodically *thinned* back to an evenly-strided subset of the
  archive (overflow factor 1.25 amortizes the O(m³) refactor over ~m/4
  appends), so tell stays O(m²) and ask latency flat into the 10⁴–10⁶
  regime.  Below the threshold the active set is the full archive and the
  posterior matches the numpy path to float64 round-off.
* **float64 without global flags** — every device call runs inside
  ``jax.experimental.enable_x64()``, a thread-local scope, so GP parity
  with the float64 numpy reference does not require flipping the process-
  wide ``jax_enable_x64`` switch under the rest of the suite (kernel and
  model code elsewhere still sees default float32).

``jnp.linalg.cholesky`` signals a non-PD input with NaNs instead of the
LinAlgError the numpy path catches, so the append jit also returns a
finiteness flag for the new diagonal block; a degenerate append falls back
to one masked full-capacity refactor (``_refactor_jit``), same as numpy.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.scipy.linalg import solve_triangular


def jax_available() -> bool:
    """Import gate for callers that must degrade gracefully (ci_smoke)."""
    return True


def _pow2(n: int) -> int:
    p = 16
    while p < n:
        p *= 2
    return p


def _pow2_small(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# jitted kernels — module-level so every JaxIncrementalGP instance shares the
# trace cache (shapes, not instances, key the cache)
# ---------------------------------------------------------------------------


def _kern(a, b, ls, signal):
    """RBF via ‖a‖² + ‖b‖² − 2a·b — the same GEMM form as the numpy path,
    so the two modes agree to float64 round-off."""
    d2 = (jnp.sum(a * a, axis=1)[:, None]
          + jnp.sum(b * b, axis=1)[None, :] - 2.0 * (a @ b.T))
    d2 = jnp.maximum(d2, 0.0)
    return signal * jnp.exp(-0.5 * d2 / (ls * ls))


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _append_jit(xb, lb, lib, n, m, xnew, ls, noise, signal):
    """Rank-append an m-row block (padded to xnew's static height B).

    ``n``/``m`` are traced int32 scalars; indices for dynamic_update_slice
    stay int32 throughout (x64 mode would otherwise mix int dtypes).
    Returns the donated buffers plus a finite-diagonal flag — NaN means the
    block was not PD (numpy raises LinAlgError here) and the caller must
    refactor.
    """
    cap = xb.shape[0]
    B = xnew.shape[0]
    zero = jnp.int32(0)
    n2 = n + m
    rows = jnp.arange(cap, dtype=jnp.int32)
    mask_old = (rows < n).astype(xb.dtype)             # pre-append valid rows
    mask_new = (rows < n2).astype(xb.dtype)
    bvalid = (jnp.arange(B, dtype=jnp.int32) < m).astype(xb.dtype)

    xb = jax.lax.dynamic_update_slice(xb, xnew * bvalid[:, None], (n, zero))
    # kernel strips against the *valid* rows only (zero-padding ⇒ mask once)
    k12 = _kern(xb, xnew, ls, signal) * mask_old[:, None] * bvalid[None, :]
    k22 = (_kern(xnew, xnew, ls, signal) + noise * jnp.eye(B, dtype=xb.dtype))
    # padding rows of the block get an identity diagonal so chol is exact
    k22 = (k22 * bvalid[:, None] * bvalid[None, :]
           + jnp.diag(1.0 - bvalid))
    w = lib @ k12                                      # (cap, B); L⁻¹K₁₂
    l22 = jnp.linalg.cholesky(k22 - w.T @ w)
    ok = jnp.all(jnp.isfinite(jnp.diagonal(l22) * bvalid + (1.0 - bvalid)))
    li22 = solve_triangular(l22, jnp.eye(B, dtype=xb.dtype), lower=True)
    lb = jax.lax.dynamic_update_slice(lb, w.T, (n, zero))
    lb = jax.lax.dynamic_update_slice(
        lb, l22, (n, n))
    lib = jax.lax.dynamic_update_slice(lib, -li22 @ (w.T @ lib), (n, zero))
    lib = jax.lax.dynamic_update_slice(lib, li22, (n, n))
    # restore the zero invariant outside the new valid n2×n2 block (the
    # identity rows of padded appends must not leak into later GEMMs)
    lb = lb * mask_new[:, None] * mask_new[None, :]
    lib = lib * mask_new[:, None] * mask_new[None, :]
    return xb, lb, lib, ok


@jax.jit
def _refactor_jit(xb, n, ls, noise, signal):
    """Masked full-capacity refactor: chol of [[K, 0], [0, I]] then re-zero.

    O(cap³) but called only on degenerate appends, thinning, and
    lengthscale refreshes — all amortized."""
    cap = xb.shape[0]
    rows = jnp.arange(cap, dtype=jnp.int32)
    mask = (rows < n).astype(xb.dtype)
    k = _kern(xb, xb, ls, signal) * mask[:, None] * mask[None, :]
    k = k + noise * jnp.eye(cap, dtype=xb.dtype) * mask \
        + jnp.diag(1.0 - mask)
    lb = jnp.linalg.cholesky(k)
    lib = solve_triangular(lb, jnp.eye(cap, dtype=xb.dtype), lower=True)
    lb = lb * mask[:, None] * mask[None, :]
    lib = lib * mask[:, None] * mask[None, :]
    return lb, lib


@jax.jit
def _fit_y_jit(lib, yn):
    """alpha = L⁻ᵀ L⁻¹ y over the full (zero-padded) capacity."""
    return lib.T @ (lib @ yn)


@jax.jit
def _predict_jit(xb, lib, alpha, n, xq, ls, signal):
    cap = xb.shape[0]
    mask = (jnp.arange(cap, dtype=jnp.int32) < n).astype(xb.dtype)
    ks = _kern(xq, xb, ls, signal) * mask[None, :]      # (P, cap)
    mu = ks @ alpha                                     # (P, J) normalized
    v = lib @ ks.T
    var = jnp.clip(signal - jnp.sum(v * v, axis=0), 1e-9, None)
    return mu, var


@jax.jit
def _predict_mean_jit(xb, alpha, n, xq, ls, signal):
    cap = xb.shape[0]
    mask = (jnp.arange(cap, dtype=jnp.int32) < n).astype(xb.dtype)
    return (_kern(xq, xb, ls, signal) * mask[None, :]) @ alpha


@jax.jit
def _ehvi_jit(xb, alpha, n, xq, front, ref, ym, ysd, ls, signal):
    """Fused: pool kernel GEMM → posterior means → denormalize → staircase
    EHVI sweep, one device call for the whole candidate pool.

    ``front`` is the sorted valid front padded with ``(ref[0], y_last)``
    sentinel rows — each contributes a zero-width segment, so the sum
    matches the unpadded numpy staircase exactly."""
    cap = xb.shape[0]
    mask = (jnp.arange(cap, dtype=jnp.int32) < n).astype(xb.dtype)
    ks = _kern(xq, xb, ls, signal) * mask[None, :]
    mu = ks @ alpha * ysd + ym                          # (P, 2) denormalized
    x, y = front[:, 0], front[:, 1]
    neg_inf = jnp.full((1,), -jnp.inf, dtype=xb.dtype)
    lows = jnp.concatenate([neg_inf, x])
    ups = jnp.concatenate([x, ref[0:1]])
    levels = jnp.concatenate([ref[1:2], y])
    width = jnp.clip(ups[None, :] - jnp.maximum(lows[None, :], mu[:, 0:1]),
                     0.0, None)
    height = jnp.clip(levels[None, :] - mu[:, 1:2], 0.0, None)
    return jnp.sum(width * height, axis=1)


class JaxIncrementalGP:
    """Drop-in for ``IncrementalGP`` with device buffers + inducing points.

    ``inducing_threshold=None`` (or a huge value) keeps every observation
    active — exact numpy parity; with a threshold, ``len(gp)`` is the
    active-set size and ``gp.n_total`` the archive size.
    """

    def __init__(self, lengthscale: float = 0.3, noise: float = 1e-3,
                 signal: float = 1.0,
                 inducing_threshold: Optional[int] = None,
                 inducing_overflow: float = 1.25):
        self.ls = float(lengthscale)
        self.noise = float(noise)
        self.signal = float(signal)
        self.inducing_threshold = inducing_threshold
        self.inducing_overflow = float(inducing_overflow)
        self._n = 0                       # active rows on device
        self._cap = 0
        self._dim = 0
        self._xb = self._lb = self._lib = None
        # full observation archive (host): the thinning source
        self._ax: Optional[np.ndarray] = None
        self._n_all = 0
        self._active_idx = np.zeros(0, np.int64)   # archive row per active row
        self.n_appends = 0
        self.n_refactors = 0
        self.n_thins = 0
        # fit state (single- and multi-target kept separate, like numpy)
        self._alpha1 = self._ym = self._ys = None
        self._alpha_m = self._ym_m = self._ys_m = None

    def __len__(self) -> int:
        return self._n

    @property
    def n_total(self) -> int:
        return self._n_all

    # -- buffers --------------------------------------------------------------
    def _ensure_cap(self, need: int, dim: int) -> None:
        if self._cap >= need and self._dim == dim:
            return
        cap = _pow2(need)
        with enable_x64():
            xb = jnp.zeros((cap, dim), jnp.float64)
            lb = jnp.zeros((cap, cap), jnp.float64)
            lib = jnp.zeros((cap, cap), jnp.float64)
            n = self._n
            if n:
                xb = xb.at[:n, :].set(self._xb[:n, :])
                lb = lb.at[:n, :n].set(self._lb[:n, :n])
                lib = lib.at[:n, :n].set(self._lib[:n, :n])
        self._xb, self._lb, self._lib = xb, lb, lib
        self._cap, self._dim = cap, dim
        act = np.zeros(cap, np.int64)
        act[:self._n] = self._active_idx[:self._n]
        self._active_idx = act

    def _archive(self, x_new: np.ndarray) -> np.ndarray:
        m = len(x_new)
        need = self._n_all + m
        if self._ax is None or len(self._ax) < need:
            cap = _pow2(need)
            ax = np.zeros((cap, x_new.shape[1]))
            if self._n_all:
                ax[:self._n_all] = self._ax[:self._n_all]
            self._ax = ax
        self._ax[self._n_all:need] = x_new
        idx = np.arange(self._n_all, need, dtype=np.int64)
        self._n_all = need
        return idx

    # -- incremental growth ---------------------------------------------------
    def observe(self, x_new: np.ndarray) -> "JaxIncrementalGP":
        x_new = np.atleast_2d(np.asarray(x_new, float))
        m = len(x_new)
        if m == 0:
            return self
        idx = self._archive(x_new)
        self._append_active(x_new, idx)
        thr = self.inducing_threshold
        if thr is not None and self._n > int(thr * self.inducing_overflow):
            self._thin()
        return self

    def _append_active(self, xa: np.ndarray, idx: np.ndarray) -> None:
        m, d = xa.shape
        B = _pow2_small(m)
        # capacity must cover the *padded* block: dynamic_update_slice
        # clamps out-of-bounds starts, which would silently corrupt rows
        self._ensure_cap(self._n + B, d)
        xpad = np.zeros((B, d))
        xpad[:m] = xa
        with enable_x64():
            self._xb, self._lb, self._lib, ok = _append_jit(
                self._xb, self._lb, self._lib,
                np.int32(self._n), np.int32(m), jnp.asarray(xpad),
                self.ls, self.noise, self.signal)
        self._active_idx[self._n:self._n + m] = idx
        self._n += m
        self.n_appends += 1
        if not bool(ok):
            # degenerate block (duplicated rows beyond the noise jitter):
            # same fallback as the numpy LinAlgError path
            self._refactor()

    def _refactor(self) -> None:
        with enable_x64():
            self._lb, self._lib = _refactor_jit(
                self._xb, np.int32(self._n), self.ls, self.noise, self.signal)
        self.n_refactors += 1

    def _thin(self) -> None:
        """Shrink the active set to an evenly-strided archive subset."""
        thr = int(self.inducing_threshold)
        sel = np.unique(np.linspace(0, self._n_all - 1, thr).round()
                        .astype(np.int64))
        xa = self._ax[sel]
        m, d = xa.shape
        self._n = 0
        self._ensure_cap(m, d)
        with enable_x64():
            self._xb = (jnp.zeros((self._cap, d), jnp.float64)
                        .at[:m, :].set(jnp.asarray(xa)))
        self._n = m
        self._active_idx[:m] = sel
        self._refactor()
        self.n_thins += 1

    def set_lengthscale(self, ls: float) -> "JaxIncrementalGP":
        """Hyperparameter refresh: new lengthscale, one masked refactor
        riding the existing device buffers."""
        ls = float(ls)
        if ls == self.ls:
            return self
        self.ls = ls
        if self._n:
            self._refactor()
        return self

    def fit_x(self, x: np.ndarray) -> "JaxIncrementalGP":
        """Reset and bulk-load (equivalence/refit entry point)."""
        self._n = 0
        self._n_all = 0
        return self.observe(x)

    # -- fits -----------------------------------------------------------------
    def _active_targets(self, Y: np.ndarray) -> np.ndarray:
        """Archive-aligned targets → active subset (SoD selection)."""
        Y = np.asarray(Y, float)
        if len(Y) == self._n:
            return Y
        assert len(Y) == self._n_all, (
            f"targets must align with the archive ({self._n_all}) or the "
            f"active set ({self._n}), got {len(Y)}")
        return Y[self._active_idx[:self._n]]

    def _padded(self, ya: np.ndarray) -> jnp.ndarray:
        out = np.zeros((self._cap,) + ya.shape[1:])
        out[:self._n] = ya
        return jnp.asarray(out)

    def fit_y(self, y: np.ndarray) -> "JaxIncrementalGP":
        assert self._n > 0, "observe first"
        ya = self._active_targets(np.asarray(y, float))
        self._ym = float(np.mean(ya))
        self._ys = float(np.std(ya)) or 1.0
        with enable_x64():
            self._alpha1 = _fit_y_jit(
                self._lib, self._padded((ya - self._ym) / self._ys)[:, None])
        return self

    def fit(self, x: np.ndarray, y: np.ndarray) -> "JaxIncrementalGP":
        return self.fit_x(x).fit_y(y)

    def fit_y_multi(self, Y: np.ndarray) -> "JaxIncrementalGP":
        assert self._n > 0, "observe first"
        ya = self._active_targets(Y)
        self._ym_m = ya.mean(axis=0)
        std = ya.std(axis=0)
        self._ys_m = np.where(std > 0, std, 1.0)
        with enable_x64():
            self._alpha_m = _fit_y_jit(
                self._lib, self._padded((ya - self._ym_m) / self._ys_m))
        return self

    # -- predicts -------------------------------------------------------------
    def _pad_pool(self, xs: np.ndarray):
        xs = np.atleast_2d(np.asarray(xs, float))
        P = _pow2_small(max(len(xs), 1))
        xq = np.zeros((P, xs.shape[1]))
        xq[:len(xs)] = xs
        # the device transfer must happen inside the x64 scope: outside it,
        # jnp.asarray silently truncates the queries to float32 and every
        # downstream GEMM runs on f32-rounded inputs (≈1e-7 posterior error
        # — the exact silent-precision bug this module exists to avoid)
        with enable_x64():
            xq = jnp.asarray(xq)
        return xq, len(xs)

    def predict(self, xs: np.ndarray):
        xq, M = self._pad_pool(xs)
        with enable_x64():
            mu, var = _predict_jit(self._xb, self._lib, self._alpha1,
                                   np.int32(self._n), xq, self.ls, self.signal)
        mu = np.asarray(mu)[:M, 0]
        sig = np.sqrt(np.asarray(var)[:M])
        return mu * self._ys + self._ym, sig * self._ys

    def predict_multi(self, xs: np.ndarray):
        xq, M = self._pad_pool(xs)
        with enable_x64():
            mu, var = _predict_jit(self._xb, self._lib, self._alpha_m,
                                   np.int32(self._n), xq, self.ls, self.signal)
        mu = np.asarray(mu)[:M] * self._ys_m + self._ym_m
        sig = np.sqrt(np.asarray(var)[:M])[:, None] * self._ys_m
        return mu, sig

    def predict_mean_multi(self, xs: np.ndarray) -> np.ndarray:
        xq, M = self._pad_pool(xs)
        with enable_x64():
            mu = _predict_mean_jit(self._xb, self._alpha_m, np.int32(self._n),
                                   xq, self.ls, self.signal)
        return np.asarray(mu)[:M] * self._ys_m + self._ym_m

    def score_ehvi(self, xs: np.ndarray, front_y: np.ndarray,
                   ref: np.ndarray) -> np.ndarray:
        """Fused EHVI over the pool: posterior means + staircase sweep in
        one device call (means are *not* round-tripped to the host)."""
        xs = np.atleast_2d(np.asarray(xs, float))
        if len(xs) == 0:
            return np.zeros(0)
        ref = np.asarray(ref, float)
        front = np.asarray(front_y, float)
        front = front[np.all(front < ref, axis=1)]
        if len(front) == 0:
            mu = self.predict_mean_multi(xs)
            return (np.clip(ref[0] - mu[:, 0], 0.0, None)
                    * np.clip(ref[1] - mu[:, 1], 0.0, None))
        from repro.core.results import nondominated_mask

        front = front[nondominated_mask(front)]
        front = front[np.argsort(front[:, 0])]
        F = _pow2_small(len(front))
        pad = np.repeat([[ref[0], front[-1, 1]]], F - len(front), axis=0)
        fpad = np.vstack([front, pad])
        xq, M = self._pad_pool(xs)
        with enable_x64():
            s = _ehvi_jit(self._xb, self._alpha_m, np.int32(self._n), xq,
                          jnp.asarray(fpad), jnp.asarray(ref),
                          jnp.asarray(self._ym_m), jnp.asarray(self._ys_m),
                          self.ls, self.signal)
        return np.asarray(s)[:M]

    def stats(self) -> dict:
        return {"n_active": self._n, "n_total": self._n_all,
                "capacity": self._cap, "appends": self.n_appends,
                "refactors": self.n_refactors, "thins": self.n_thins}
