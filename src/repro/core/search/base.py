"""SearchAlgorithm ABC — the plug-in point for "any search tool" (paper §I).

ask/tell protocol: ``ask(n)`` returns up to n knob dicts to evaluate (batched,
so multi-client JHosts keep every board busy); ``tell(knobs, y)`` reports the
objective vector (always minimised).
"""
from __future__ import annotations

import abc
from typing import Dict, List

import numpy as np

from repro.core.space import DesignSpace


class SearchAlgorithm(abc.ABC):
    def __init__(self, space: DesignSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.history_x: List[Dict] = []
        self.history_y: List[np.ndarray] = []

    @abc.abstractmethod
    def ask(self, n: int) -> List[Dict]:
        ...

    def tell(self, knobs: Dict, y: np.ndarray) -> None:
        self.history_x.append(dict(knobs))
        self.history_y.append(np.asarray(y, float))

    # -- helpers -------------------------------------------------------------
    def _key(self, knobs: Dict) -> tuple:
        return tuple(sorted((k, str(v)) for k, v in knobs.items()))

    def observed_points(self) -> np.ndarray:
        return (np.stack([self.space.encode(x) for x in self.history_x])
                if self.history_x else np.zeros((0, len(self.space.knobs))))

    def observed_values(self) -> np.ndarray:
        return (np.stack(self.history_y)
                if self.history_y else np.zeros((0, 0)))
