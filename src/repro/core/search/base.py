"""SearchAlgorithm ABC — the plug-in point for "any search tool" (paper §I).

ask/tell protocol: ``ask(n)`` returns up to n knob dicts to evaluate (batched,
so multi-client JHosts keep every board busy); ``tell(knobs, y)`` reports the
objective vector (always minimised).

Shadow-aware candidate pools: when the fleet scheduler exposes which sw
fingerprints its clients already hold compiled (``note_residency``), a
``residency_bias`` fraction of every ``_fresh_pool`` sample has its sw
columns overwritten with an already-resident sw combination before dedup —
the searcher keeps exploring the hw ladder freely but stops proposing
compile storms.  With no residency reported (the default) the sampling path
and rng stream are bit-identical to before.
"""
from __future__ import annotations

import abc
from typing import Callable, Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core.space import DesignSpace, KIND_SW


class SearchAlgorithm(abc.ABC):
    def __init__(self, space: DesignSpace, seed: int = 0,
                 residency_bias: float = 0.5):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.history_x: List[Dict] = []
        self.history_y: List[np.ndarray] = []
        self.residency_bias = residency_bias
        self._sw_fp_fn: Optional[Callable[[Dict], object]] = None
        self._resident_fps: frozenset = frozenset()
        self._fp_to_sw: Dict[object, np.ndarray] = {}

    @abc.abstractmethod
    def ask(self, n: int) -> List[Dict]:
        ...

    def tell(self, knobs: Dict, y: np.ndarray) -> None:
        self.history_x.append(dict(knobs))
        self.history_y.append(np.asarray(y, float))
        if self._sw_fp_fn is not None:
            fp = self._sw_fp_fn(knobs)
            if fp not in self._fp_to_sw:
                self._fp_to_sw[fp] = \
                    self.space.index_encode(knobs)[self._sw_cols()]

    # -- shadow-aware pools --------------------------------------------------
    def set_sw_fingerprint_fn(self, fn: Optional[Callable[[Dict], object]]
                              ) -> None:
        """Install the knobs→sw-fingerprint map (the fleet's cache key), so
        tells can record which sw index combination each fingerprint is."""
        self._sw_fp_fn = fn

    def note_residency(self, fps: Iterable) -> None:
        """Update the set of sw fingerprints currently compiled somewhere in
        the fleet (union of healthy clients' cache shadows)."""
        self._resident_fps = frozenset(fps)

    def _sw_cols(self) -> np.ndarray:
        if not hasattr(self, "_sw_cols_cache"):
            self._sw_cols_cache = np.asarray(
                [i for i, k in enumerate(self.space.knobs)
                 if k.kind == KIND_SW], np.int64)
        return self._sw_cols_cache

    def _resident_sw_combos(self) -> Optional[np.ndarray]:
        """(R, n_sw) index rows for resident fingerprints we have seen told,
        in deterministic (sorted-by-repr) order; None when biasing cannot
        engage."""
        if not self._resident_fps or not self._fp_to_sw:
            return None
        rows = [self._fp_to_sw[fp]
                for fp in sorted(self._resident_fps & self._fp_to_sw.keys(),
                                 key=repr)]
        return np.stack(rows) if rows else None

    # -- helpers -------------------------------------------------------------
    def _key(self, knobs: Dict) -> tuple:
        return tuple(sorted((k, str(v)) for k, v in knobs.items()))

    def _flat_mults(self) -> np.ndarray:
        if not hasattr(self, "_flat_mults_cache"):
            mults, acc = [], 1
            for k in self.space.knobs:
                mults.append(acc)
                acc *= len(k.values)
            self._flat_mults_cache = np.asarray(mults, np.int64)
        return self._flat_mults_cache

    def _flat_keys(self, idx: np.ndarray) -> np.ndarray:
        """Mixed-radix flat index per row of an ``(n, K)`` index matrix —
        the vectorized dedup key (one int64 dot instead of building a
        sorted tuple of strings per config).  For spaces larger than 2⁶³
        configs the dot wraps; a wraparound collision at worst skips a
        candidate, it never corrupts search state."""
        with np.errstate(over="ignore"):
            return np.asarray(idx, np.int64) @ self._flat_mults()

    def _flat_key(self, knobs: Dict) -> int:
        return int(self._flat_keys(self.space.index_encode(knobs)[None])[0])

    def _fresh_pool(self, size: int, exclude: Optional[Set[int]] = None,
                    max_rounds: int = 50
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Candidate pool of distinct, not-yet-excluded configs, vectorized.

        Replaces the config-at-a-time ``while`` loops the model-based
        searchers used to duplicate: each round samples the whole remainder
        as index arrays in one shot (``DesignSpace.sample_index_batch``),
        drops in-pool duplicates (``np.unique`` on flat keys, first
        occurrence wins so draw order is preserved) and anything in
        ``exclude`` (the caller's already-dispatched flat keys), and tops
        up until full.  Draws from ``self.rng`` — the one stream the scalar
        path used.  Returns ``(idx, coords, flats)``: the ``(P, K)`` value-
        index matrix, the encoded [0, 1] coordinate matrix, and the flat
        dedup key per row — all arrays; callers decode to knob dicts only
        for the handful of configs they actually pick.

        A nearly-exhausted space cannot fill the pool: after ``max_rounds``
        the partial pool is returned instead of spinning forever.

        Residency biasing (see module docstring): when resident sw combos
        are known, the first ``residency_bias`` fraction of each round's
        sample keeps its hw columns but adopts a resident sw combo, before
        dedup — so biased duplicates still collapse and the pool stays
        distinct.  The extra rng draws happen only when biasing engages.
        """
        exclude = exclude if exclude is not None else set()
        combos = self._resident_sw_combos()
        sw_cols = self._sw_cols() if combos is not None else None
        have: Set[int] = set()
        picked_idx: List[np.ndarray] = []
        n_picked = 0
        for _ in range(max_rounds):
            need = size - n_picked
            if need <= 0:
                break
            # mild oversampling keeps the round count low once duplicates
            # against `exclude` become common late in a run
            idx = self.space.sample_index_batch(self.rng, need + (need >> 1) + 4)
            if combos is not None and len(sw_cols):
                nb = int(len(idx) * self.residency_bias)
                if nb:
                    pick = self.rng.integers(0, len(combos), nb)
                    idx[:nb][:, sw_cols] = combos[pick]
            flats = self._flat_keys(idx)
            _, first = np.unique(flats, return_index=True)
            take = []
            for i in np.sort(first):                 # preserve draw order
                if n_picked + len(take) >= size:
                    break
                f = int(flats[i])
                if f in have or f in exclude:
                    continue
                have.add(f)
                take.append(i)
            if take:
                picked_idx.append(idx[np.asarray(take)])
                n_picked += len(take)
        k = len(self.space.knobs)
        idx = (np.vstack(picked_idx) if picked_idx
               else np.zeros((0, k), np.int64))
        return idx, self.space.encode_index_batch(idx), self._flat_keys(idx)

    def observed_points(self) -> np.ndarray:
        return (np.stack([self.space.encode(x) for x in self.history_x])
                if self.history_x else np.zeros((0, len(self.space.knobs))))

    def observed_values(self) -> np.ndarray:
        return (np.stack(self.history_y)
                if self.history_y else np.zeros((0, 0)))
