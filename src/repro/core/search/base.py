"""SearchAlgorithm ABC — the plug-in point for "any search tool" (paper §I).

ask/tell protocol: ``ask(n)`` returns up to n knob dicts to evaluate (batched,
so multi-client JHosts keep every board busy); ``tell(knobs, y)`` reports the
objective vector (always minimised).
"""
from __future__ import annotations

import abc
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.space import DesignSpace


class SearchAlgorithm(abc.ABC):
    def __init__(self, space: DesignSpace, seed: int = 0):
        self.space = space
        self.rng = np.random.default_rng(seed)
        self.history_x: List[Dict] = []
        self.history_y: List[np.ndarray] = []

    @abc.abstractmethod
    def ask(self, n: int) -> List[Dict]:
        ...

    def tell(self, knobs: Dict, y: np.ndarray) -> None:
        self.history_x.append(dict(knobs))
        self.history_y.append(np.asarray(y, float))

    # -- helpers -------------------------------------------------------------
    def _key(self, knobs: Dict) -> tuple:
        return tuple(sorted((k, str(v)) for k, v in knobs.items()))

    def _flat_mults(self) -> np.ndarray:
        if not hasattr(self, "_flat_mults_cache"):
            mults, acc = [], 1
            for k in self.space.knobs:
                mults.append(acc)
                acc *= len(k.values)
            self._flat_mults_cache = np.asarray(mults, np.int64)
        return self._flat_mults_cache

    def _flat_keys(self, idx: np.ndarray) -> np.ndarray:
        """Mixed-radix flat index per row of an ``(n, K)`` index matrix —
        the vectorized dedup key (one int64 dot instead of building a
        sorted tuple of strings per config).  For spaces larger than 2⁶³
        configs the dot wraps; a wraparound collision at worst skips a
        candidate, it never corrupts search state."""
        with np.errstate(over="ignore"):
            return np.asarray(idx, np.int64) @ self._flat_mults()

    def _flat_key(self, knobs: Dict) -> int:
        return int(self._flat_keys(self.space.index_encode(knobs)[None])[0])

    def _fresh_pool(self, size: int, exclude: Optional[Set[int]] = None,
                    max_rounds: int = 50
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Candidate pool of distinct, not-yet-excluded configs, vectorized.

        Replaces the config-at-a-time ``while`` loops the model-based
        searchers used to duplicate: each round samples the whole remainder
        as index arrays in one shot (``DesignSpace.sample_index_batch``),
        drops in-pool duplicates (``np.unique`` on flat keys, first
        occurrence wins so draw order is preserved) and anything in
        ``exclude`` (the caller's already-dispatched flat keys), and tops
        up until full.  Draws from ``self.rng`` — the one stream the scalar
        path used.  Returns ``(idx, coords, flats)``: the ``(P, K)`` value-
        index matrix, the encoded [0, 1] coordinate matrix, and the flat
        dedup key per row — all arrays; callers decode to knob dicts only
        for the handful of configs they actually pick.

        A nearly-exhausted space cannot fill the pool: after ``max_rounds``
        the partial pool is returned instead of spinning forever.
        """
        exclude = exclude if exclude is not None else set()
        have: Set[int] = set()
        picked_idx: List[np.ndarray] = []
        n_picked = 0
        for _ in range(max_rounds):
            need = size - n_picked
            if need <= 0:
                break
            # mild oversampling keeps the round count low once duplicates
            # against `exclude` become common late in a run
            idx = self.space.sample_index_batch(self.rng, need + (need >> 1) + 4)
            flats = self._flat_keys(idx)
            _, first = np.unique(flats, return_index=True)
            take = []
            for i in np.sort(first):                 # preserve draw order
                if n_picked + len(take) >= size:
                    break
                f = int(flats[i])
                if f in have or f in exclude:
                    continue
                have.add(f)
                take.append(i)
            if take:
                picked_idx.append(idx[np.asarray(take)])
                n_picked += len(take)
        k = len(self.space.knobs)
        idx = (np.vstack(picked_idx) if picked_idx
               else np.zeros((0, k), np.int64))
        return idx, self.space.encode_index_batch(idx), self._flat_keys(idx)

    def observed_points(self) -> np.ndarray:
        return (np.stack([self.space.encode(x) for x in self.history_x])
                if self.history_x else np.zeros((0, len(self.space.knobs))))

    def observed_values(self) -> np.ndarray:
        return (np.stack(self.history_y)
                if self.history_y else np.zeros((0, 0)))
