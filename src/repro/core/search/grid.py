"""Strided grid sweep — exhaustive enumeration order shuffled by a linear
congruential stride so truncated budgets still cover the space uniformly."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.search.base import SearchAlgorithm


class GridSearch(SearchAlgorithm):
    def __init__(self, space, seed: int = 0):
        super().__init__(space, seed)
        self._sizes = [len(k.values) for k in space.knobs]
        self._n = int(np.prod(self._sizes))
        # coprime stride => a permutation of the flat index space
        self._stride = self._pick_stride()
        self._offset = int(self.rng.integers(self._n))
        self._i = 0

    def _pick_stride(self) -> int:
        cand = max(3, int(self._n * 0.6180339887))
        while np.gcd(cand, self._n) != 1:
            cand += 1
        return cand

    def _unflatten(self, flat: int) -> Dict:
        cfg = {}
        for k, s in zip(self.space.knobs, self._sizes):
            cfg[k.name] = k.values[flat % s]
            flat //= s
        return cfg

    def ask(self, n: int) -> List[Dict]:
        out = []
        for _ in range(n):
            if self._i >= self._n:
                self._i = 0  # wrap (finite space exhausted)
            flat = (self._offset + self._i * self._stride) % self._n
            out.append(self._unflatten(flat))
            self._i += 1
        return out
