from repro.core.search.base import SearchAlgorithm
from repro.core.search.random_search import RandomSearch
from repro.core.search.grid import GridSearch
from repro.core.search.nsga2 import NSGA2
from repro.core.search.bayesopt import BayesOpt, GP, IncrementalGP, PAL
from repro.core.search.driver import SearchDriver
from repro.core.search.hypervolume import hypervolume, hypervolume_2d, hypervolume_3d

ALGORITHMS = {
    "random": RandomSearch,
    "grid": GridSearch,
    "nsga2": NSGA2,
    "bayesopt": BayesOpt,
    "pal": PAL,
}
