from repro.core.search.base import SearchAlgorithm
from repro.core.search.random_search import RandomSearch
from repro.core.search.grid import GridSearch
from repro.core.search.nsga2 import NSGA2
from repro.core.search.bayesopt import (BayesOpt, GP, IncrementalGP, PAL,
                                        tune_lengthscale)
from repro.core.search.driver import SearchDriver
# JaxIncrementalGP is intentionally NOT imported here: gp_jax imports jax at
# module load, and the numpy search stack must keep working without it —
# use ``from repro.core.search.gp_jax import JaxIncrementalGP`` (or
# ``gp_mode="jax"``, which imports it lazily).
from repro.core.search.hypervolume import hypervolume, hypervolume_2d, hypervolume_3d

ALGORITHMS = {
    "random": RandomSearch,
    "grid": GridSearch,
    "nsga2": NSGA2,
    "bayesopt": BayesOpt,
    "pal": PAL,
}
