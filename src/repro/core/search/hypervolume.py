"""Exact hypervolume indicators (minimisation, reference point dominated by
all fronts).  2-D: sweep; 3-D: slicing over the third objective."""
from __future__ import annotations

import numpy as np

from repro.core.results import nondominated_mask


def hypervolume_2d(points: np.ndarray, ref: np.ndarray) -> float:
    pts = np.asarray(points, float)
    ref = np.asarray(ref, float)
    pts = pts[np.all(pts < ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[nondominated_mask(pts)]
    pts = pts[np.argsort(pts[:, 0])]
    hv, prev_y = 0.0, ref[1]
    for x, y in pts:
        hv += (ref[0] - x) * (prev_y - y)
        prev_y = y
    return float(hv)


def hypervolume_3d(points: np.ndarray, ref: np.ndarray) -> float:
    pts = np.asarray(points, float)
    pts = pts[np.all(pts < ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[nondominated_mask(pts)]
    zs = np.concatenate([np.unique(pts[:, 2]), ref[2:3]])  # ascending slab edges
    hv = 0.0
    for lo, hi in zip(zs[:-1], zs[1:]):
        active = pts[pts[:, 2] <= lo][:, :2]
        hv += hypervolume_2d(active, ref[:2]) * (hi - lo)
    return float(hv)


def hypervolume(points: np.ndarray, ref: np.ndarray) -> float:
    m = np.asarray(ref).shape[0]
    if m == 2:
        return hypervolume_2d(points, ref)
    if m == 3:
        return hypervolume_3d(points, ref)
    raise NotImplementedError(f"hypervolume for M={m}")
