"""Gaussian-process Bayesian optimisation (paper refs [2], [6], [8]).

Multi-objective handling à la ParEGO: each ask draws a random weight vector,
scalarises observed objectives with the augmented Tchebycheff norm, fits a GP
on the normalised ordinal encoding, and maximises Expected Improvement over a
random candidate pool (discrete spaces make gradient ascent pointless).  An
EHVI-greedy variant is also provided: candidates are scored by the exact 2-D
hypervolume improvement of the GP posterior mean.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.search.base import SearchAlgorithm
from repro.core.search.hypervolume import hypervolume_2d
from repro.core.results import nondominated_mask


class GP:
    """Tiny RBF-kernel GP with observation noise (pure numpy)."""

    def __init__(self, lengthscale: float = 0.3, noise: float = 1e-3,
                 signal: float = 1.0):
        self.ls = lengthscale
        self.noise = noise
        self.signal = signal
        self._x: Optional[np.ndarray] = None

    def _k(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = np.sum((a[:, None, :] - b[None, :, :]) ** 2, -1)
        return self.signal * np.exp(-0.5 * d2 / self.ls ** 2)

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GP":
        self._x = x
        self._ym = float(np.mean(y))
        self._ys = float(np.std(y)) or 1.0
        yn = (y - self._ym) / self._ys
        k = self._k(x, x) + self.noise * np.eye(len(x))
        self._l = np.linalg.cholesky(k)
        self._alpha = np.linalg.solve(self._l.T, np.linalg.solve(self._l, yn))
        return self

    def predict(self, xs: np.ndarray):
        ks = self._k(xs, self._x)
        mu = ks @ self._alpha
        v = np.linalg.solve(self._l, ks.T)
        var = np.clip(self.signal - np.sum(v * v, axis=0), 1e-9, None)
        return mu * self._ys + self._ym, np.sqrt(var) * self._ys


def expected_improvement(mu: np.ndarray, sigma: np.ndarray, best: float) -> np.ndarray:
    from scipy.stats import norm

    z = (best - mu) / sigma
    return (best - mu) * norm.cdf(z) + sigma * norm.pdf(z)


class BayesOpt(SearchAlgorithm):
    def __init__(self, space, seed: int = 0, n_init: int = 12,
                 pool_size: int = 512, strategy: str = "parego"):
        super().__init__(space, seed)
        self.n_init = n_init
        self.pool_size = pool_size
        assert strategy in ("parego", "ehvi")
        self.strategy = strategy
        self._seen = set()

    def _pool(self) -> List[Dict]:
        pool, keys = [], set()
        while len(pool) < self.pool_size:
            c = self.space.sample(self.rng)
            k = self._key(c)
            if k in keys or k in self._seen:
                continue
            keys.add(k)
            pool.append(c)
        return pool

    def _scalarise(self, ys: np.ndarray) -> np.ndarray:
        lo, hi = ys.min(0), ys.max(0)
        z = (ys - lo) / np.where(hi - lo > 0, hi - lo, 1.0)
        w = self.rng.dirichlet(np.ones(ys.shape[1]))
        return np.max(w * z, axis=1) + 0.05 * np.sum(w * z, axis=1)

    def ask(self, n: int) -> List[Dict]:
        out: List[Dict] = []
        ys = self.observed_values()
        if len(self.history_x) < self.n_init:
            while len(out) < n:
                c = self.space.sample(self.rng)
                if self._key(c) not in self._seen:
                    self._seen.add(self._key(c))
                    out.append(c)
            return out

        xs = self.observed_points()
        pool = self._pool()
        xp = np.stack([self.space.encode(c) for c in pool])
        for _ in range(n):
            if self.strategy == "parego" or ys.shape[1] != 2:
                s = self._scalarise(ys)
                gp = GP().fit(xs, s)
                mu, sig = gp.predict(xp)
                score = expected_improvement(mu, sig, float(np.min(s)))
            else:  # ehvi-greedy on posterior means
                mus = []
                for j in range(ys.shape[1]):
                    mu, _ = GP().fit(xs, ys[:, j]).predict(xp)
                    mus.append(mu)
                mus = np.stack(mus, axis=1)
                ref = ys.max(0) * 1.1 + 1e-9
                base = hypervolume_2d(ys, ref)
                score = np.asarray([
                    hypervolume_2d(np.vstack([ys, m[None]]), ref) - base
                    for m in mus])
            order = np.argsort(-score)
            for i in order:
                if self._key(pool[i]) not in self._seen:
                    self._seen.add(self._key(pool[i]))
                    out.append(pool[i])
                    break
            else:
                out.append(self.space.sample(self.rng))
        return out


class PAL(SearchAlgorithm):
    """ε-PAL-lite (Zuluaga et al., ICML 2013 — the paper's reference [4]):
    GP per objective; sample the candidate whose posterior uncertainty is
    largest among points that could still be Pareto-optimal."""

    def __init__(self, space, seed: int = 0, n_init: int = 12,
                 pool_size: int = 512, beta: float = 1.8):
        super().__init__(space, seed)
        self.n_init = n_init
        self.pool_size = pool_size
        self.beta = beta
        self._seen = set()

    def ask(self, n: int) -> List[Dict]:
        out: List[Dict] = []
        ys = self.observed_values()
        if len(self.history_x) < self.n_init:
            while len(out) < n:
                c = self.space.sample(self.rng)
                if self._key(c) not in self._seen:
                    self._seen.add(self._key(c))
                    out.append(c)
            return out

        xs = self.observed_points()
        pool, keys = [], set()
        while len(pool) < self.pool_size:
            c = self.space.sample(self.rng)
            k = self._key(c)
            if k not in keys and k not in self._seen:
                keys.add(k)
                pool.append(c)
        xp = np.stack([self.space.encode(c) for c in pool])
        mus, sigs = [], []
        for j in range(ys.shape[1]):
            mu, sig = GP().fit(xs, ys[:, j]).predict(xp)
            mus.append(mu)
            sigs.append(sig)
        mu = np.stack(mus, 1)
        sig = np.stack(sigs, 1)
        lcb = mu - self.beta * sig
        # potentially Pareto-optimal = optimistic value not dominated by any
        # observed point
        maybe = np.asarray([
            not np.any(np.all(ys <= l, axis=1) & np.any(ys < l, axis=1))
            for l in lcb])
        width = np.sum(sig, axis=1) * np.where(maybe, 1.0, 0.05)
        for i in np.argsort(-width):
            if len(out) >= n:
                break
            if self._key(pool[i]) in self._seen:
                continue
            self._seen.add(self._key(pool[i]))
            out.append(pool[i])
        while len(out) < n:
            out.append(self.space.sample(self.rng))
        return out
