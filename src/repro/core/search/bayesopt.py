"""Gaussian-process Bayesian optimisation (paper refs [2], [6], [8]).

Multi-objective handling à la ParEGO: each ask draws a random weight vector,
scalarises observed objectives with the augmented Tchebycheff norm, fits a GP
on the normalised ordinal encoding, and maximises Expected Improvement over a
random candidate pool (discrete spaces make gradient ascent pointless).  An
EHVI-greedy variant is also provided: candidates are scored by the exact 2-D
hypervolume improvement of the GP posterior mean.

Batch-aware internals: the GP kernel matrix depends only on the observed
*inputs*, so one Cholesky factorisation (``GP.fit_x``) is shared by every
objective / scalarisation / pick within an ask (``GP.fit_y`` re-solves for
the new targets against the cached factor).  EHVI scoring is one vectorized
incremental-hypervolume sweep over the sorted front for the whole candidate
pool — no per-candidate ``hypervolume_2d`` calls.

Incremental GP (``gp_mode="incremental"``, the default): instead of
refactoring K(X, X) from scratch every ask — O(n³) in observed points —
each ``tell`` appends its row to preallocated (amortized-doubling) kernel /
Cholesky buffers with a rank-append update, O(n²) per new observation.  The
factor is cached across asks and invalidated only by new data, so an ask is
pure O(n²·pool) BLAS.  ``gp_mode="refit"`` keeps the per-ask refactor (the
pre-incremental path, retained for benchmarking and equivalence tests).
``gp_mode="jax"`` moves the same incremental layout onto the accelerator
(``repro.core.search.gp_jax.JaxIncrementalGP``): jitted donated-buffer
rank-appends, fused pool scoring in one device call, and a subset-of-data
inducing-point approximation past ``inducing_threshold`` points so ask
latency stays flat at 10⁴+ observations.  The numpy path is the reference;
the jax path matches it to float64 round-off while the active set is exact.
Candidate pools come from the vectorized ``SearchAlgorithm._fresh_pool``
(one ``sample_index_batch`` sweep, no config-at-a-time Python loop).

Hyperparameter refresh (``hyper_refresh_every=k``, any mode): every k tells
the RBF lengthscale is re-tuned on a strided subsample (median-distance
heuristic candidates scored by Gaussian log marginal likelihood —
``tune_lengthscale``) and the live factor is rebuilt *in place* via
``set_lengthscale`` — one refactor riding the existing buffers, not a
rebuild of the searcher.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.search.base import SearchAlgorithm
from repro.core.search.hypervolume import hypervolume_2d
from repro.core.results import nondominated_mask

GP_MODES = ("incremental", "refit", "jax")

DEFAULT_LENGTHSCALE = 0.3


def _make_surrogate(gp_mode: str, inducing_threshold: Optional[int]):
    """The persistent surrogate for a searcher: numpy incremental buffers, or
    the device-resident jax twin (imported lazily so jax-less environments
    can still use the numpy modes)."""
    if gp_mode == "jax":
        from repro.core.search.gp_jax import JaxIncrementalGP

        return JaxIncrementalGP(inducing_threshold=inducing_threshold)
    return IncrementalGP()


def tune_lengthscale(xs: np.ndarray, ys: np.ndarray, current: float,
                     noise: float = 1e-3, signal: float = 1.0,
                     max_points: int = 256) -> float:
    """Re-tune the RBF lengthscale on a strided subsample, deterministically.

    Candidates are the median positive pairwise distance of the subsample and
    its half/double (plus the incumbent); each is scored by the Gaussian log
    marginal likelihood summed over per-column-standardized target columns,
    so the schedule needs no gradient machinery and costs one small O(m³)
    factorisation per candidate (m ≤ ``max_points``).  Returns the incumbent
    unchanged when there is too little data to score.
    """
    xs = np.asarray(xs, float)
    ys = np.asarray(ys, float)
    if ys.ndim == 1:
        ys = ys[:, None]
    n = len(xs)
    if n < 4:
        return float(current)
    sel = np.unique(np.linspace(0, n - 1, min(n, max_points)).round()
                    .astype(int))
    x, Y = xs[sel], ys[sel]
    m = len(x)
    sq = np.einsum("ij,ij->i", x, x)
    d2 = np.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    pos = d2[np.triu_indices(m, 1)]
    pos = pos[pos > 0]
    if not len(pos):
        return float(current)
    med = float(np.sqrt(np.median(pos)))
    cands = sorted({round(float(c), 6)
                    for c in (current, 0.5 * med, med, 2.0 * med)
                    if c > 1e-6})
    std = Y.std(axis=0)
    yn = (Y - Y.mean(axis=0)) / np.where(std > 0, std, 1.0)
    best_ls, best_ml = float(current), -np.inf
    for ls in cands:
        k = signal * np.exp(-0.5 * d2 / ls ** 2) + noise * np.eye(m)
        try:
            L = np.linalg.cholesky(k)
        except np.linalg.LinAlgError:
            continue
        a = np.linalg.solve(L, yn)
        # log ML up to constants: -½ yᵀK⁻¹y - J·log|L|, summed over columns
        ml = (-0.5 * float(np.sum(a * a))
              - Y.shape[1] * float(np.sum(np.log(np.diag(L)))))
        if ml > best_ml:
            best_ml, best_ls = ml, ls
    return best_ls


class GP:
    """Tiny RBF-kernel GP with observation noise (pure numpy).

    ``fit_x`` factors the kernel matrix once; ``fit_y`` solves for new
    targets against the cached Cholesky factor, so a batch ask that predicts
    several target vectors on the same observations pays for one
    factorisation total.
    """

    def __init__(self, lengthscale: float = 0.3, noise: float = 1e-3,
                 signal: float = 1.0):
        self.ls = lengthscale
        self.noise = noise
        self.signal = signal
        self._x: Optional[np.ndarray] = None

    def _k(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = np.sum((a[:, None, :] - b[None, :, :]) ** 2, -1)
        return self.signal * np.exp(-0.5 * d2 / self.ls ** 2)

    def fit_x(self, x: np.ndarray) -> "GP":
        """Factor K(x, x) + σ²I once; reusable across any number of targets."""
        self._x = x
        k = self._k(x, x) + self.noise * np.eye(len(x))
        self._l = np.linalg.cholesky(k)
        return self

    def fit_y(self, y: np.ndarray) -> "GP":
        """Solve for a target vector against the cached Cholesky factor."""
        assert self._x is not None, "fit_x first"
        self._ym = float(np.mean(y))
        self._ys = float(np.std(y)) or 1.0
        yn = (y - self._ym) / self._ys
        self._alpha = np.linalg.solve(self._l.T, np.linalg.solve(self._l, yn))
        return self

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GP":
        return self.fit_x(x).fit_y(y)

    def predict(self, xs: np.ndarray):
        ks = self._k(xs, self._x)
        mu = ks @ self._alpha
        v = np.linalg.solve(self._l, ks.T)
        var = np.clip(self.signal - np.sum(v * v, axis=0), 1e-9, None)
        return mu * self._ys + self._ym, np.sqrt(var) * self._ys

    def set_lengthscale(self, ls: float) -> "GP":
        """Adopt a re-tuned lengthscale; refactors in place if already fit."""
        self.ls = float(ls)
        if self._x is not None:
            self.fit_x(self._x)
        return self


class IncrementalGP(GP):
    """GP grown one ``tell`` at a time: rank-append Cholesky, O(n²)/update.

    ``observe(x_new)`` appends m rows to preallocated amortized-doubling
    buffers for X, the kernel matrix K, the Cholesky factor L, and L⁻¹.
    With L⁻¹ maintained explicitly, the append's triangular solve
    ``w = L₁₁⁻¹ K₁₂`` and every downstream ``fit_y``/``predict`` solve are
    plain matmuls — O(n²) BLAS with no LAPACK refactor anywhere on the hot
    path (numpy has no triangular solve; ``np.linalg.solve`` would LU-factor
    the triangle at O(n³) again).  The factor persists across asks and only
    new data extends it, so an ask after t tells costs O(n²·pool) instead of
    the O(n³) ``fit_x`` refactor.  A numerically degenerate append (exactly
    duplicated rows beyond what the noise jitter absorbs) falls back to one
    full refactor — still amortized.
    """

    def __init__(self, lengthscale: float = 0.3, noise: float = 1e-3,
                 signal: float = 1.0):
        super().__init__(lengthscale, noise, signal)
        self._n = 0
        self._cap = 0
        self._xb = self._kb = self._lb = self._lib = None

    def __len__(self) -> int:
        return self._n

    def _grow(self, need: int, dim: int) -> None:
        if self._cap >= need:
            return
        cap = max(self._cap, 16)
        while cap < need:
            cap *= 2
        xb = np.zeros((cap, dim))
        kb = np.zeros((cap, cap))
        lb = np.zeros((cap, cap))
        lib = np.zeros((cap, cap))
        n = self._n
        if n:
            xb[:n] = self._xb[:n]
            kb[:n, :n] = self._kb[:n, :n]
            lb[:n, :n] = self._lb[:n, :n]
            lib[:n, :n] = self._lib[:n, :n]
        self._xb, self._kb, self._lb, self._lib = xb, kb, lb, lib
        self._cap = cap

    def _sync_views(self) -> None:
        n = self._n
        self._x = self._xb[:n]
        self._l = self._lb[:n, :n]
        self._li = self._lib[:n, :n]

    def _refactor(self) -> None:
        """Full O(n³) rebuild of L and L⁻¹ from the stored kernel matrix."""
        n = self._n
        self._lb[:n, :n] = np.linalg.cholesky(self._kb[:n, :n])
        self._lib[:n, :n] = np.linalg.solve(self._lb[:n, :n], np.eye(n))

    def observe(self, x_new: np.ndarray) -> "IncrementalGP":
        """Append m observation inputs; O(n²·m) against the cached factor."""
        x_new = np.atleast_2d(np.asarray(x_new, float))
        m = len(x_new)
        if m == 0:
            return self
        n = self._n
        self._grow(n + m, x_new.shape[1])
        # the kernel matrix grows in place
        k12 = self._k(self._xb[:n], x_new)                    # (n, m)
        k22 = self._k(x_new, x_new) + self.noise * np.eye(m)
        self._xb[n:n + m] = x_new
        self._kb[:n, n:n + m] = k12
        self._kb[n:n + m, :n] = k12.T
        self._kb[n:n + m, n:n + m] = k22
        self._n = n + m
        # rank-append: L_new = [[L, 0], [wᵀ, chol(K₂₂ - wᵀw)]]
        w = self._lib[:n, :n] @ k12                           # (n, m)
        try:
            l22 = np.linalg.cholesky(k22 - w.T @ w)
        except np.linalg.LinAlgError:
            self._refactor()
            self._sync_views()
            return self
        li22 = np.linalg.solve(l22, np.eye(m))                # m is tiny
        self._lb[n:n + m, :n] = w.T
        self._lb[n:n + m, n:n + m] = l22
        self._lib[n:n + m, :n] = -li22 @ (w.T @ self._lib[:n, :n])
        self._lib[n:n + m, n:n + m] = li22
        self._sync_views()
        return self

    def _k(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """RBF kernel via ‖a‖² + ‖b‖² − 2a·b — one GEMM instead of the
        (N, M, K) subtract/square/sum broadcast.  Same values to fp round-
        off; the GEMM releases the GIL, which is what lets the async
        SearchDriver genuinely overlap GP math with client evaluation."""
        a = np.asarray(a, float)
        b = np.asarray(b, float)
        d2 = (np.einsum("ij,ij->i", a, a)[:, None]
              + np.einsum("ij,ij->i", b, b)[None, :] - 2.0 * (a @ b.T))
        np.maximum(d2, 0.0, out=d2)
        return self.signal * np.exp(-0.5 * d2 / self.ls ** 2)

    def fit_x(self, x: np.ndarray) -> "IncrementalGP":
        """Reset and bulk-load (equivalence/refit entry point)."""
        self._n = 0
        return self.observe(x)

    def fit_y(self, y: np.ndarray) -> "IncrementalGP":
        assert self._n > 0, "observe first"
        self._ym = float(np.mean(y))
        self._ys = float(np.std(y)) or 1.0
        yn = (y - self._ym) / self._ys
        self._alpha = self._li.T @ (self._li @ yn)
        return self

    def predict(self, xs: np.ndarray):
        ks = self._k(xs, self._x)
        mu = ks @ self._alpha
        v = self._li @ ks.T
        var = np.clip(self.signal - np.sum(v * v, axis=0), 1e-9, None)
        return mu * self._ys + self._ym, np.sqrt(var) * self._ys

    # -- multi-target path: one kernel sweep for every objective ------------
    def fit_y_multi(self, Y: np.ndarray) -> "IncrementalGP":
        """Solve for all J target columns at once against the cached factor
        (the per-objective ``fit_y``/``predict`` pairs each recomputed the
        candidate kernel block — the dominant per-ask cost)."""
        assert self._n > 0, "observe first"
        Y = np.asarray(Y, float)
        self._ym_m = Y.mean(axis=0)
        std = Y.std(axis=0)
        self._ys_m = np.where(std > 0, std, 1.0)
        yn = (Y - self._ym_m) / self._ys_m
        self._alpha_m = self._li.T @ (self._li @ yn)          # (n, J)
        return self

    def predict_multi(self, xs: np.ndarray):
        """(mu, sigma), each (M, J), from one ``_k``/solve sweep."""
        ks = self._k(xs, self._x)
        mu = ks @ self._alpha_m * self._ys_m + self._ym_m
        v = self._li @ ks.T
        var = np.clip(self.signal - np.sum(v * v, axis=0), 1e-9, None)
        return mu, np.sqrt(var)[:, None] * self._ys_m

    def predict_mean_multi(self, xs: np.ndarray) -> np.ndarray:
        """Posterior means only — skips the (n, M) variance solve that
        EHVI scoring (means-greedy) never uses."""
        return self._k(xs, self._x) @ self._alpha_m * self._ys_m + self._ym_m

    def set_lengthscale(self, ls: float) -> "IncrementalGP":
        """Adopt a re-tuned lengthscale riding the existing buffers: the
        stored kernel matrix is recomputed in place and refactored once —
        no searcher rebuild, no buffer reallocation."""
        ls = float(ls)
        if ls == self.ls:
            return self
        self.ls = ls
        n = self._n
        if n:
            self._kb[:n, :n] = (self._k(self._xb[:n], self._xb[:n])
                                + self.noise * np.eye(n))
            self._refactor()
            self._sync_views()
        return self


# ---------------------------------------------------------------------------
# normal CDF/PDF — pure numpy, no per-ask scipy import on the hot path
# ---------------------------------------------------------------------------

_SQRT2 = math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)


def _erf(x: np.ndarray) -> np.ndarray:
    """Vectorized erf (Abramowitz & Stegun 7.1.26, |err| < 1.5e-7)."""
    x = np.asarray(x, float)
    sign = np.sign(x)
    a = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * a)
    poly = t * (0.254829592 + t * (-0.284496736 + t * (
        1.421413741 + t * (-1.453152027 + t * 1.061405429))))
    return sign * (1.0 - poly * np.exp(-a * a))


def norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(np.asarray(z, float) / _SQRT2))


def norm_pdf(z: np.ndarray) -> np.ndarray:
    z = np.asarray(z, float)
    return _INV_SQRT_2PI * np.exp(-0.5 * z * z)


def expected_improvement(mu: np.ndarray, sigma: np.ndarray, best: float) -> np.ndarray:
    z = (best - mu) / sigma
    return (best - mu) * norm_cdf(z) + sigma * norm_pdf(z)


def ehvi_improvements(ys: np.ndarray, ref: np.ndarray,
                      cand: np.ndarray) -> np.ndarray:
    """Exact 2-D hypervolume improvement of each candidate over the front.

    One vectorized staircase sweep for the whole ``(M, 2)`` candidate set:
    the nondominated front of ``ys`` (sorted by the first objective) defines
    x-segments with constant cover height; a candidate's improvement is the
    sum over segments of (uncovered width) × (uncovered height).  Equals
    ``hypervolume_2d(ys ∪ {c}, ref) - hypervolume_2d(ys, ref)`` per
    candidate, without M front re-sweeps.
    """
    cand = np.asarray(cand, float)
    ys = np.asarray(ys, float)
    ref = np.asarray(ref, float)
    front = ys[np.all(ys < ref, axis=1)]
    if len(front) == 0:
        return (np.clip(ref[0] - cand[:, 0], 0.0, None)
                * np.clip(ref[1] - cand[:, 1], 0.0, None))
    front = front[nondominated_mask(front)]
    front = front[np.argsort(front[:, 0])]
    x, y = front[:, 0], front[:, 1]          # x ascending ⇒ y descending
    # segment j covers [lows[j], ups[j]) with the front covering y-range
    # [levels[j], ref1]; j = 0 is the uncovered strip left of the front
    lows = np.concatenate(([-np.inf], x))
    ups = np.concatenate((x, ref[0:1]))
    levels = np.concatenate((ref[1:2], y))
    width = np.clip(ups[None, :] - np.maximum(lows[None, :], cand[:, 0:1]),
                    0.0, None)
    height = np.clip(levels[None, :] - cand[:, 1:2], 0.0, None)
    return np.sum(width * height, axis=1)


def _ehvi_improvements_loop(ys: np.ndarray, ref: np.ndarray,
                            cand: np.ndarray) -> np.ndarray:
    """Reference per-candidate implementation (kept for equivalence tests)."""
    base = hypervolume_2d(ys, ref)
    return np.asarray([hypervolume_2d(np.vstack([ys, m[None]]), ref) - base
                       for m in cand])


class BayesOpt(SearchAlgorithm):
    def __init__(self, space, seed: int = 0, n_init: int = 12,
                 pool_size: int = 512, strategy: str = "parego",
                 gp_mode: str = "incremental",
                 hyper_refresh_every: Optional[int] = None,
                 inducing_threshold: Optional[int] = 5000):
        super().__init__(space, seed)
        self.n_init = n_init
        self.pool_size = pool_size
        assert strategy in ("parego", "ehvi")
        assert gp_mode in GP_MODES
        self.strategy = strategy
        self.gp_mode = gp_mode
        self.hyper_refresh_every = hyper_refresh_every
        self._gp = _make_surrogate(gp_mode, inducing_threshold)
        self._gp_pending: List[np.ndarray] = []
        self._front_y: Optional[np.ndarray] = None   # maintained Pareto front
        self._seen = set()
        self._ls = DEFAULT_LENGTHSCALE        # refit-mode tuned lengthscale
        self._last_refresh = 0
        self.n_hyper_refreshes = 0

    def tell(self, knobs: Dict, y: np.ndarray) -> None:
        super().tell(knobs, y)
        if self.gp_mode in ("incremental", "jax"):
            # queued for a single block rank-append at the next ask boundary
            # (one O(n²·m) BLAS append for m tells instead of m tiny ones)
            self._gp_pending.append(self.space.encode(knobs))
            self._update_front(np.asarray(y, float))

    def _maybe_refresh(self, gp, ys: np.ndarray):
        """The hyperparameter refresh schedule: every ``hyper_refresh_every``
        tells, re-tune the lengthscale and rebuild the live factor in place
        (``set_lengthscale``); refit mode carries the tuned value into its
        next per-ask factorisation instead."""
        every = self.hyper_refresh_every
        if not every or len(self.history_x) - self._last_refresh < every:
            return gp
        self._last_refresh = len(self.history_x)
        current = self._ls if self.gp_mode == "refit" else gp.ls
        ls = tune_lengthscale(self.observed_points(), ys, current)
        self.n_hyper_refreshes += 1
        if self.gp_mode == "refit":
            if ls != self._ls:
                self._ls = ls
                return GP(lengthscale=ls).fit_x(self.observed_points())
            return gp
        return gp.set_lengthscale(ls)

    def _update_front(self, y: np.ndarray) -> None:
        """O(front) incremental Pareto update, so EHVI asks never rescan all
        n observations for the nondominated set."""
        if self._front_y is None or self._front_y.shape[1] != len(y):
            self._front_y = y[None, :]
            return
        f = self._front_y
        le = np.all(f <= y, axis=1)
        if np.any(le & np.any(f < y, axis=1)):
            return                                   # dominated: front unchanged
        if np.any(le & np.all(y <= f, axis=1)):
            return                                   # exact duplicate of a
        keep = ~(np.all(y <= f, axis=1) & np.any(y < f, axis=1))   # front row
        self._front_y = np.vstack([f[keep], y[None, :]])

    def _surrogate(self) -> GP:
        """The ask-time GP: the cached incremental factor — extended by one
        rank-append over the tells since the last ask, invalidated only by
        new data — or, in refit mode, a fresh O(n³) factorisation (the
        pre-incremental path, kept for benchmarking and equivalence)."""
        if self.gp_mode in ("incremental", "jax"):
            if self._gp_pending:
                self._gp.observe(np.stack(self._gp_pending))
                self._gp_pending.clear()
            return self._gp
        return GP(lengthscale=self._ls).fit_x(self.observed_points())

    def _scalarise(self, ys: np.ndarray) -> np.ndarray:
        lo, hi = ys.min(0), ys.max(0)
        z = (ys - lo) / np.where(hi - lo > 0, hi - lo, 1.0)
        w = self.rng.dirichlet(np.ones(ys.shape[1]))
        return np.max(w * z, axis=1) + 0.05 * np.sum(w * z, axis=1)

    def _take_best(self, idx: np.ndarray, flats: np.ndarray,
                   order: np.ndarray, n: int, out: List[Dict]) -> None:
        """Append up to n unseen pool members in score order, pad randomly.

        The pool stays arrays throughout scoring; only the few configs
        actually picked are decoded to knob dicts here."""
        for i in order:
            if len(out) >= n:
                return
            f = int(flats[i])
            if f not in self._seen:
                self._seen.add(f)
                out.append(self.space.index_decode(idx[i]))
        while len(out) < n:
            out.append(self.space.sample(self.rng))

    def ask(self, n: int) -> List[Dict]:
        out: List[Dict] = []
        ys = self.observed_values()
        if len(self.history_x) < self.n_init:
            while len(out) < n:
                c = self.space.sample(self.rng)
                k = self._flat_key(c)
                if k not in self._seen:
                    self._seen.add(k)
                    out.append(c)
            return out

        idx, xp, flats = self._fresh_pool(self.pool_size, exclude=self._seen)
        gp = self._surrogate()   # one cached/derived factor for every pick
        gp = self._maybe_refresh(gp, ys)

        if self.strategy == "ehvi" and ys.shape[1] == 2:
            # posterior means per objective (shared factor), then one
            # vectorized incremental-HVI sweep scores the whole pool; the
            # scores do not change between picks, so the n picks are simply
            # the n best-scoring unseen candidates
            ref = ys.max(0) * 1.1 + 1e-9
            if self.gp_mode == "jax":
                # fully fused on device: kernel GEMM, posterior means, and
                # the staircase sweep happen in one jit call — no (M, 2)
                # means matrix ever lands on the host
                gp.fit_y_multi(ys)
                score = gp.score_ehvi(xp, self._front_y, ref)
            elif self.gp_mode == "incremental":
                # one mean-only kernel sweep for both objectives, scored
                # against the maintained front (same staircase as passing
                # all of ys: ehvi reduces to the nondominated set anyway)
                mus = gp.fit_y_multi(ys).predict_mean_multi(xp)
                score = ehvi_improvements(self._front_y, ref, mus)
            else:
                mus = np.stack([gp.fit_y(ys[:, j]).predict(xp)[0]
                                for j in range(ys.shape[1])], axis=1)
                score = ehvi_improvements(ys, ref, mus)
            self._take_best(idx, flats, np.argsort(-score), n, out)
            return out

        for _ in range(n):   # parego: fresh scalarisation per pick
            s = self._scalarise(ys)
            mu, sig = gp.fit_y(s).predict(xp)
            score = expected_improvement(mu, sig, float(np.min(s)))
            self._take_best(idx, flats, np.argsort(-score), len(out) + 1, out)
        return out


def pal_maybe_pareto(ys: np.ndarray, lcb: np.ndarray) -> np.ndarray:
    """Vectorized "potentially Pareto-optimal" mask for PAL.

    True where a candidate's optimistic (LCB) objective vector is not
    dominated by any observed point — one ``(M, N, K)`` broadcast instead of
    a Python loop over the pool.
    """
    dom = (np.all(ys[None, :, :] <= lcb[:, None, :], axis=2)
           & np.any(ys[None, :, :] < lcb[:, None, :], axis=2))
    return ~np.any(dom, axis=1)


def _pal_maybe_pareto_loop(ys: np.ndarray, lcb: np.ndarray) -> np.ndarray:
    """Reference list-comprehension version (kept for equivalence tests)."""
    return np.asarray([
        not np.any(np.all(ys <= l, axis=1) & np.any(ys < l, axis=1))
        for l in lcb])


class PAL(SearchAlgorithm):
    """ε-PAL-lite (Zuluaga et al., ICML 2013 — the paper's reference [4]):
    GP per objective; sample the candidate whose posterior uncertainty is
    largest among points that could still be Pareto-optimal.

    Mean-only fast path (``mean_only=True``, incremental mode): like the
    real ε-PAL, a candidate whose optimistic (LCB) objective box was found
    dominated is *classified* — ruled out of the race permanently.  When
    such a point re-enters a later candidate pool, its posterior is taken
    from ``IncrementalGP.predict_mean_multi`` — means only, skipping the
    ``(n, M)`` variance solve that dominates predict cost — and it scores
    zero sampling width, so it can never outrank an unclassified candidate.
    ``n_mean_only`` counts pool rows that rode the fast path.
    """

    def __init__(self, space, seed: int = 0, n_init: int = 12,
                 pool_size: int = 512, beta: float = 1.8,
                 gp_mode: str = "incremental", mean_only: bool = True,
                 hyper_refresh_every: Optional[int] = None,
                 inducing_threshold: Optional[int] = 5000):
        super().__init__(space, seed)
        self.n_init = n_init
        self.pool_size = pool_size
        self.beta = beta
        assert gp_mode in GP_MODES
        self.gp_mode = gp_mode
        self.mean_only = mean_only
        self.hyper_refresh_every = hyper_refresh_every
        self._gp = _make_surrogate(gp_mode, inducing_threshold)
        self._gp_pending: List[np.ndarray] = []
        self._seen = set()
        self._ruled_out: set = set()          # flat keys classified not-Pareto
        self._ruled_out_arr: Optional[np.ndarray] = None
        self.n_mean_only = 0
        self._ls = DEFAULT_LENGTHSCALE        # refit-mode tuned lengthscale
        self._last_refresh = 0
        self.n_hyper_refreshes = 0

    def tell(self, knobs: Dict, y: np.ndarray) -> None:
        super().tell(knobs, y)
        if self.gp_mode in ("incremental", "jax"):
            self._gp_pending.append(self.space.encode(knobs))

    _maybe_refresh = BayesOpt._maybe_refresh

    def _classified_mask(self, flats: np.ndarray) -> np.ndarray:
        if not self._ruled_out:
            return np.zeros(len(flats), bool)
        if self._ruled_out_arr is None or \
                len(self._ruled_out_arr) != len(self._ruled_out):
            self._ruled_out_arr = np.fromiter(
                self._ruled_out, np.int64, len(self._ruled_out))
        return np.isin(flats, self._ruled_out_arr)

    def ask(self, n: int) -> List[Dict]:
        out: List[Dict] = []
        ys = self.observed_values()
        if len(self.history_x) < self.n_init:
            while len(out) < n:
                c = self.space.sample(self.rng)
                k = self._flat_key(c)
                if k not in self._seen:
                    self._seen.add(k)
                    out.append(c)
            return out

        idx, xp, flats = self._fresh_pool(self.pool_size, exclude=self._seen)
        # shared (cached in incremental mode) factor across per-objective fits
        if self.gp_mode in ("incremental", "jax"):
            if self._gp_pending:
                self._gp.observe(np.stack(self._gp_pending))
                self._gp_pending.clear()
            gp = self._maybe_refresh(self._gp, ys).fit_y_multi(ys)
            known = (self._classified_mask(flats)
                     if self.mean_only else np.zeros(len(flats), bool))
            if known.any():
                # classified points: means only, zero width — the variance
                # solve is skipped for the whole classified slice
                mu = np.empty((len(xp), ys.shape[1]))
                sig = np.zeros_like(mu)
                fresh = ~known
                if fresh.any():
                    mu[fresh], sig[fresh] = gp.predict_multi(xp[fresh])
                mu[known] = gp.predict_mean_multi(xp[known])
                self.n_mean_only += int(known.sum())
            else:
                mu, sig = gp.predict_multi(xp)
        else:
            known = np.zeros(len(flats), bool)
            gp = GP(lengthscale=self._ls).fit_x(self.observed_points())
            gp = self._maybe_refresh(gp, ys)
            mus, sigs = [], []
            for j in range(ys.shape[1]):
                m, s = gp.fit_y(ys[:, j]).predict(xp)
                mus.append(m)
                sigs.append(s)
            mu = np.stack(mus, 1)
            sig = np.stack(sigs, 1)
        lcb = mu - self.beta * sig
        maybe = pal_maybe_pareto(ys, lcb)
        if self.mean_only and self.gp_mode in ("incremental", "jax"):
            # a full-posterior LCB box found dominated is a permanent
            # classification (the ε-PAL discard step)
            for f in flats[~maybe & ~known]:
                self._ruled_out.add(int(f))
        width = np.sum(sig, axis=1) * np.where(maybe, 1.0, 0.05)
        for i in np.argsort(-width):
            if len(out) >= n:
                break
            f = int(flats[i])
            if f in self._seen:
                continue
            self._seen.add(f)
            out.append(self.space.index_decode(idx[i]))
        while len(out) < n:
            out.append(self.space.sample(self.rng))
        return out
