"""Gaussian-process Bayesian optimisation (paper refs [2], [6], [8]).

Multi-objective handling à la ParEGO: each ask draws a random weight vector,
scalarises observed objectives with the augmented Tchebycheff norm, fits a GP
on the normalised ordinal encoding, and maximises Expected Improvement over a
random candidate pool (discrete spaces make gradient ascent pointless).  An
EHVI-greedy variant is also provided: candidates are scored by the exact 2-D
hypervolume improvement of the GP posterior mean.

Batch-aware internals: the GP kernel matrix depends only on the observed
*inputs*, so one Cholesky factorisation (``GP.fit_x``) is shared by every
objective / scalarisation / pick within an ask (``GP.fit_y`` re-solves for
the new targets against the cached factor).  EHVI scoring is one vectorized
incremental-hypervolume sweep over the sorted front for the whole candidate
pool — no per-candidate ``hypervolume_2d`` calls.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.search.base import SearchAlgorithm
from repro.core.search.hypervolume import hypervolume_2d
from repro.core.results import nondominated_mask


class GP:
    """Tiny RBF-kernel GP with observation noise (pure numpy).

    ``fit_x`` factors the kernel matrix once; ``fit_y`` solves for new
    targets against the cached Cholesky factor, so a batch ask that predicts
    several target vectors on the same observations pays for one
    factorisation total.
    """

    def __init__(self, lengthscale: float = 0.3, noise: float = 1e-3,
                 signal: float = 1.0):
        self.ls = lengthscale
        self.noise = noise
        self.signal = signal
        self._x: Optional[np.ndarray] = None

    def _k(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = np.sum((a[:, None, :] - b[None, :, :]) ** 2, -1)
        return self.signal * np.exp(-0.5 * d2 / self.ls ** 2)

    def fit_x(self, x: np.ndarray) -> "GP":
        """Factor K(x, x) + σ²I once; reusable across any number of targets."""
        self._x = x
        k = self._k(x, x) + self.noise * np.eye(len(x))
        self._l = np.linalg.cholesky(k)
        return self

    def fit_y(self, y: np.ndarray) -> "GP":
        """Solve for a target vector against the cached Cholesky factor."""
        assert self._x is not None, "fit_x first"
        self._ym = float(np.mean(y))
        self._ys = float(np.std(y)) or 1.0
        yn = (y - self._ym) / self._ys
        self._alpha = np.linalg.solve(self._l.T, np.linalg.solve(self._l, yn))
        return self

    def fit(self, x: np.ndarray, y: np.ndarray) -> "GP":
        return self.fit_x(x).fit_y(y)

    def predict(self, xs: np.ndarray):
        ks = self._k(xs, self._x)
        mu = ks @ self._alpha
        v = np.linalg.solve(self._l, ks.T)
        var = np.clip(self.signal - np.sum(v * v, axis=0), 1e-9, None)
        return mu * self._ys + self._ym, np.sqrt(var) * self._ys


def expected_improvement(mu: np.ndarray, sigma: np.ndarray, best: float) -> np.ndarray:
    from scipy.stats import norm

    z = (best - mu) / sigma
    return (best - mu) * norm.cdf(z) + sigma * norm.pdf(z)


def ehvi_improvements(ys: np.ndarray, ref: np.ndarray,
                      cand: np.ndarray) -> np.ndarray:
    """Exact 2-D hypervolume improvement of each candidate over the front.

    One vectorized staircase sweep for the whole ``(M, 2)`` candidate set:
    the nondominated front of ``ys`` (sorted by the first objective) defines
    x-segments with constant cover height; a candidate's improvement is the
    sum over segments of (uncovered width) × (uncovered height).  Equals
    ``hypervolume_2d(ys ∪ {c}, ref) - hypervolume_2d(ys, ref)`` per
    candidate, without M front re-sweeps.
    """
    cand = np.asarray(cand, float)
    ys = np.asarray(ys, float)
    ref = np.asarray(ref, float)
    front = ys[np.all(ys < ref, axis=1)]
    if len(front) == 0:
        return (np.clip(ref[0] - cand[:, 0], 0.0, None)
                * np.clip(ref[1] - cand[:, 1], 0.0, None))
    front = front[nondominated_mask(front)]
    front = front[np.argsort(front[:, 0])]
    x, y = front[:, 0], front[:, 1]          # x ascending ⇒ y descending
    # segment j covers [lows[j], ups[j]) with the front covering y-range
    # [levels[j], ref1]; j = 0 is the uncovered strip left of the front
    lows = np.concatenate(([-np.inf], x))
    ups = np.concatenate((x, ref[0:1]))
    levels = np.concatenate((ref[1:2], y))
    width = np.clip(ups[None, :] - np.maximum(lows[None, :], cand[:, 0:1]),
                    0.0, None)
    height = np.clip(levels[None, :] - cand[:, 1:2], 0.0, None)
    return np.sum(width * height, axis=1)


def _ehvi_improvements_loop(ys: np.ndarray, ref: np.ndarray,
                            cand: np.ndarray) -> np.ndarray:
    """Reference per-candidate implementation (kept for equivalence tests)."""
    base = hypervolume_2d(ys, ref)
    return np.asarray([hypervolume_2d(np.vstack([ys, m[None]]), ref) - base
                       for m in cand])


class BayesOpt(SearchAlgorithm):
    def __init__(self, space, seed: int = 0, n_init: int = 12,
                 pool_size: int = 512, strategy: str = "parego"):
        super().__init__(space, seed)
        self.n_init = n_init
        self.pool_size = pool_size
        assert strategy in ("parego", "ehvi")
        self.strategy = strategy
        self._seen = set()

    def _pool(self) -> List[Dict]:
        pool, keys = [], set()
        while len(pool) < self.pool_size:
            c = self.space.sample(self.rng)
            k = self._key(c)
            if k in keys or k in self._seen:
                continue
            keys.add(k)
            pool.append(c)
        return pool

    def _scalarise(self, ys: np.ndarray) -> np.ndarray:
        lo, hi = ys.min(0), ys.max(0)
        z = (ys - lo) / np.where(hi - lo > 0, hi - lo, 1.0)
        w = self.rng.dirichlet(np.ones(ys.shape[1]))
        return np.max(w * z, axis=1) + 0.05 * np.sum(w * z, axis=1)

    def _take_best(self, pool: List[Dict], order: np.ndarray, n: int,
                   out: List[Dict]) -> None:
        """Append up to n unseen pool members in score order, pad randomly."""
        for i in order:
            if len(out) >= n:
                return
            if self._key(pool[i]) not in self._seen:
                self._seen.add(self._key(pool[i]))
                out.append(pool[i])
        while len(out) < n:
            out.append(self.space.sample(self.rng))

    def ask(self, n: int) -> List[Dict]:
        out: List[Dict] = []
        ys = self.observed_values()
        if len(self.history_x) < self.n_init:
            while len(out) < n:
                c = self.space.sample(self.rng)
                if self._key(c) not in self._seen:
                    self._seen.add(self._key(c))
                    out.append(c)
            return out

        xs = self.observed_points()
        pool = self._pool()
        xp = np.stack([self.space.encode(c) for c in pool])
        gp = GP().fit_x(xs)   # one Cholesky for every pick in this ask

        if self.strategy == "ehvi" and ys.shape[1] == 2:
            # posterior means per objective (shared factor), then one
            # vectorized incremental-HVI sweep scores the whole pool; the
            # scores do not change between picks, so the n picks are simply
            # the n best-scoring unseen candidates
            mus = np.stack([gp.fit_y(ys[:, j]).predict(xp)[0]
                            for j in range(ys.shape[1])], axis=1)
            ref = ys.max(0) * 1.1 + 1e-9
            score = ehvi_improvements(ys, ref, mus)
            self._take_best(pool, np.argsort(-score), n, out)
            return out

        for _ in range(n):   # parego: fresh scalarisation per pick
            s = self._scalarise(ys)
            mu, sig = gp.fit_y(s).predict(xp)
            score = expected_improvement(mu, sig, float(np.min(s)))
            self._take_best(pool, np.argsort(-score), len(out) + 1, out)
        return out


def pal_maybe_pareto(ys: np.ndarray, lcb: np.ndarray) -> np.ndarray:
    """Vectorized "potentially Pareto-optimal" mask for PAL.

    True where a candidate's optimistic (LCB) objective vector is not
    dominated by any observed point — one ``(M, N, K)`` broadcast instead of
    a Python loop over the pool.
    """
    dom = (np.all(ys[None, :, :] <= lcb[:, None, :], axis=2)
           & np.any(ys[None, :, :] < lcb[:, None, :], axis=2))
    return ~np.any(dom, axis=1)


def _pal_maybe_pareto_loop(ys: np.ndarray, lcb: np.ndarray) -> np.ndarray:
    """Reference list-comprehension version (kept for equivalence tests)."""
    return np.asarray([
        not np.any(np.all(ys <= l, axis=1) & np.any(ys < l, axis=1))
        for l in lcb])


class PAL(SearchAlgorithm):
    """ε-PAL-lite (Zuluaga et al., ICML 2013 — the paper's reference [4]):
    GP per objective; sample the candidate whose posterior uncertainty is
    largest among points that could still be Pareto-optimal."""

    def __init__(self, space, seed: int = 0, n_init: int = 12,
                 pool_size: int = 512, beta: float = 1.8):
        super().__init__(space, seed)
        self.n_init = n_init
        self.pool_size = pool_size
        self.beta = beta
        self._seen = set()

    def ask(self, n: int) -> List[Dict]:
        out: List[Dict] = []
        ys = self.observed_values()
        if len(self.history_x) < self.n_init:
            while len(out) < n:
                c = self.space.sample(self.rng)
                if self._key(c) not in self._seen:
                    self._seen.add(self._key(c))
                    out.append(c)
            return out

        xs = self.observed_points()
        pool, keys = [], set()
        while len(pool) < self.pool_size:
            c = self.space.sample(self.rng)
            k = self._key(c)
            if k not in keys and k not in self._seen:
                keys.add(k)
                pool.append(c)
        xp = np.stack([self.space.encode(c) for c in pool])
        gp = GP().fit_x(xs)   # shared Cholesky across the per-objective fits
        mus, sigs = [], []
        for j in range(ys.shape[1]):
            mu, sig = gp.fit_y(ys[:, j]).predict(xp)
            mus.append(mu)
            sigs.append(sig)
        mu = np.stack(mus, 1)
        sig = np.stack(sigs, 1)
        lcb = mu - self.beta * sig
        maybe = pal_maybe_pareto(ys, lcb)
        width = np.sum(sig, axis=1) * np.where(maybe, 1.0, 0.05)
        for i in np.argsort(-width):
            if len(out) >= n:
                break
            if self._key(pool[i]) in self._seen:
                continue
            self._seen.add(self._key(pool[i]))
            out.append(pool[i])
        while len(out) < n:
            out.append(self.space.sample(self.rng))
        return out
