"""NSGA-II (Deb et al., PPSN 2000 — the paper's reference [7]).

Generational evolutionary multi-objective search adapted to the ask/tell
protocol: ``ask`` hands out unevaluated individuals of the current
generation; once the whole generation is told, parents+children undergo fast
non-dominated sorting + crowding-distance selection and a new child
population is bred by binary tournament, uniform crossover and ±1 ordinal
mutation (the knob ladders are ordered, so step mutation is meaningful).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.search.base import SearchAlgorithm


def fast_nondominated_sort(ys: np.ndarray) -> List[np.ndarray]:
    """Non-domination fronts via one ``(N, N, K)`` broadcast.

    The full pairwise domination matrix is computed in one shot; front
    peeling is then pure counter arithmetic (subtract each peeled front's
    row-sums) instead of the O(N²) Python double loop.  Front membership and
    order match the loop reference (``_fast_nondominated_sort_loop``).
    """
    ys = np.asarray(ys, float)
    n = len(ys)
    if n == 0:
        return []
    le = np.all(ys[:, None, :] <= ys[None, :, :], axis=2)
    lt = np.any(ys[:, None, :] < ys[None, :, :], axis=2)
    dominates = le & lt                       # [i, j]: i dominates j
    dom_count = dominates.sum(axis=0)
    assigned = np.zeros(n, bool)
    fronts = []
    current = np.where(dom_count == 0)[0]
    while current.size:
        fronts.append(current)
        assigned[current] = True
        dom_count = dom_count - dominates[current].sum(axis=0)
        current = np.where((dom_count == 0) & ~assigned)[0]
    return fronts


def _fast_nondominated_sort_loop(ys: np.ndarray) -> List[np.ndarray]:
    """Reference O(N²) Python implementation (kept for equivalence tests)."""
    n = len(ys)
    dominated_by = [[] for _ in range(n)]
    dom_count = np.zeros(n, int)
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            if np.all(ys[i] <= ys[j]) and np.any(ys[i] < ys[j]):
                dominated_by[i].append(j)
            elif np.all(ys[j] <= ys[i]) and np.any(ys[j] < ys[i]):
                dom_count[i] += 1
    fronts = []
    current = np.where(dom_count == 0)[0]
    while len(current):
        fronts.append(current)
        nxt = []
        for i in current:
            for j in dominated_by[i]:
                dom_count[j] -= 1
                if dom_count[j] == 0:
                    nxt.append(j)
        current = np.asarray(sorted(set(nxt)), int)
    return fronts


def crowding_distance(ys: np.ndarray) -> np.ndarray:
    n, m = ys.shape
    dist = np.zeros(n)
    if n <= 2:
        return np.full(n, np.inf)
    for k in range(m):
        order = np.argsort(ys[:, k])
        span = ys[order[-1], k] - ys[order[0], k]
        dist[order[0]] = dist[order[-1]] = np.inf
        if span <= 0:
            continue
        dist[order[1:-1]] += (ys[order[2:], k] - ys[order[:-2], k]) / span
    return dist


class NSGA2(SearchAlgorithm):
    def __init__(self, space, seed: int = 0, pop_size: int = 24,
                 p_crossover: float = 0.9, p_mutate: float = 0.25):
        super().__init__(space, seed)
        self.pop_size = pop_size
        self.p_crossover = p_crossover
        self.p_mutate = p_mutate
        self._pending: List[Dict] = [space.sample(self.rng) for _ in range(pop_size)]
        self._gen_x: List[Dict] = []
        self._gen_y: List[np.ndarray] = []
        self._parents_x: List[Dict] = []
        self._parents_y: List[np.ndarray] = []

    # -- ask/tell ------------------------------------------------------------
    def ask(self, n: int) -> List[Dict]:
        out = []
        while len(out) < n:
            if not self._pending:
                self._pending = [self.space.mutate(self.space.sample(self.rng), self.rng)
                                 for _ in range(max(1, n - len(out)))]
            out.append(self._pending.pop(0))
        return out

    def tell(self, knobs: Dict, y: np.ndarray) -> None:
        super().tell(knobs, y)
        self._gen_x.append(dict(knobs))
        self._gen_y.append(np.asarray(y, float))
        if len(self._gen_x) >= self.pop_size:
            self._evolve()

    # -- internals ------------------------------------------------------------
    def _select(self, xs: List[Dict], ys: np.ndarray) -> List[int]:
        """Environmental selection to pop_size via fronts + crowding."""
        chosen: List[int] = []
        for front in fast_nondominated_sort(ys):
            if len(chosen) + len(front) <= self.pop_size:
                chosen.extend(front.tolist())
            else:
                cd = crowding_distance(ys[front])
                order = front[np.argsort(-cd)]
                chosen.extend(order[: self.pop_size - len(chosen)].tolist())
                break
        return chosen

    def _tournament(self, ys: np.ndarray, ranks: np.ndarray, cd: np.ndarray) -> int:
        i, j = self.rng.integers(len(ys)), self.rng.integers(len(ys))
        if ranks[i] != ranks[j]:
            return i if ranks[i] < ranks[j] else j
        return i if cd[i] >= cd[j] else j

    def _evolve(self) -> None:
        xs = self._parents_x + self._gen_x
        ys_list = self._parents_y + self._gen_y
        ys = np.stack(ys_list)
        idx = self._select(xs, ys)
        self._parents_x = [xs[i] for i in idx]
        self._parents_y = [ys_list[i] for i in idx]
        self._gen_x, self._gen_y = [], []

        pys = np.stack(self._parents_y)
        fronts = fast_nondominated_sort(pys)
        ranks = np.zeros(len(pys), int)
        for r, f in enumerate(fronts):
            ranks[f] = r
        cd = np.zeros(len(pys))
        for f in fronts:
            cd[f] = crowding_distance(pys[f])

        children: List[Dict] = []
        seen = set()
        while len(children) < self.pop_size:
            a = self._parents_x[self._tournament(pys, ranks, cd)]
            b = self._parents_x[self._tournament(pys, ranks, cd)]
            if self.rng.random() < self.p_crossover:
                child = {k.name: (a if self.rng.random() < 0.5 else b)[k.name]
                         for k in self.space.knobs}
            else:
                child = dict(a)
            child = self.space.mutate(child, self.rng, self.p_mutate)
            key = self._key(child)
            if key in seen:
                child = self.space.sample(self.rng)
                key = self._key(child)
            seen.add(key)
            children.append(child)
        self._pending = children
