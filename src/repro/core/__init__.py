"""JExplore core — the paper's contribution, TPU-native.

JHost orchestrates search over N JClients; JConfig manages the knob space;
JMeasure measures; results stream to CSV.  See DESIGN.md.
"""
from repro.core.space import DesignSpace, Knob, tpu_pod_space, KIND_HW, KIND_SW
from repro.core.jconfig import JConfig, TestConfig
from repro.core.jmeasure import JMeasure, JTime, JPower, JMemory, DEFAULT_MEASURES
from repro.core.fleet import FleetArtifactStore
from repro.core.jclient import JClient
from repro.core.jhost import JHost
from repro.core.results import ResultRecord, ResultStore, nondominated_mask
from repro.core.scheduler import Chunk, ClientSlot, DispatchScheduler
from repro.core import codec, transport
from repro.core.search import (
    ALGORITHMS, SearchAlgorithm, SearchDriver, RandomSearch, GridSearch,
    NSGA2, BayesOpt, GP, IncrementalGP, PAL, hypervolume,
)
