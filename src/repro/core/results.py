"""Result store + CSV export + Pareto utilities (paper §III "utility
functions such as saving the explored search space in CSV format")."""
from __future__ import annotations

import csv
import dataclasses
import json
import os
import threading
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class ResultRecord:
    config_id: int
    arch: str
    shape: str
    knobs: Dict[str, Any]
    metrics: Dict[str, float]
    status: str = "ok"            # ok | failed | timeout
    client_id: int = -1
    cached: bool = False
    wall_s: float = 0.0

    def to_wire(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_wire(d: dict) -> "ResultRecord":
        # drop frame sidecar fields (e.g. cache_info) and anything a newer
        # client may attach: the record schema is the host's contract
        return ResultRecord(**{k: v for k, v in d.items()
                               if k in _RECORD_FIELDS})


_RECORD_FIELDS = frozenset(f.name for f in dataclasses.fields(ResultRecord))


def nondominated_mask(points: np.ndarray) -> np.ndarray:
    """points (N, M), minimisation.  True where no other point dominates.

    One ``(B, N, M)`` broadcast per ≤512-row block (blocked so huge stores
    don't allocate an N² intermediate) instead of a Python loop over rows —
    this sits on the per-ask EHVI hot path.
    """
    points = np.asarray(points)
    n = len(points)
    mask = np.ones(n, bool)
    for lo in range(0, n, 512):
        blk = points[lo:lo + 512]                       # (B, M)
        le = np.all(points[:, None, :] <= blk[None, :, :], axis=2)
        lt = np.any(points[:, None, :] < blk[None, :, :], axis=2)
        mask[lo:lo + 512] = ~np.any(le & lt, axis=0)
    return mask


def _nondominated_mask_loop(points: np.ndarray) -> np.ndarray:
    """Reference per-row implementation (kept for equivalence tests)."""
    n = len(points)
    mask = np.ones(n, bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominates = np.all(points <= points[i], axis=1) & np.any(points < points[i], axis=1)
        if np.any(dominates):
            mask[i] = False
    return mask


class ResultStore:
    """Streaming record sink.

    The CSV schema is the **union** of every knob/metric key seen so far —
    not whatever the first record happened to carry (a leading timeout/failed
    record with empty metrics used to freeze a header without ``metric.*``
    columns, silently dropping every later metric via extrasaction=ignore).
    When a record introduces a new column, the file is rewritten in place
    with the widened header; pre-seed ``knob_names``/``metric_names`` (e.g.
    from the design space + objectives) to avoid rewrites entirely.
    """

    _BASE_FIELDS = ("config_id", "arch", "shape", "status", "client_id",
                    "cached", "wall_s")

    def __init__(self, csv_path: Optional[str] = None,
                 knob_names: Sequence[str] = (),
                 metric_names: Sequence[str] = ()):
        self.records: List[ResultRecord] = []
        self._csv_path = csv_path
        self._lock = threading.Lock()
        self._csv_file = None
        self._csv_writer = None
        self._knob_names = set(knob_names)
        self._metric_names = set(metric_names)
        self._written_rows: List[Dict[str, Any]] = []   # rows on disk

    def add(self, rec: ResultRecord) -> None:
        with self._lock:
            self.records.append(rec)
            if self._csv_path:
                self._append_csv(rec)

    # -- CSV ---------------------------------------------------------------
    def _fieldnames(self) -> List[str]:
        return (list(self._BASE_FIELDS)
                + [f"knob.{k}" for k in sorted(self._knob_names)]
                + [f"metric.{k}" for k in sorted(self._metric_names)])

    def _flatten(self, rec: ResultRecord) -> Dict[str, Any]:
        row = {"config_id": rec.config_id, "arch": rec.arch, "shape": rec.shape,
               "status": rec.status, "client_id": rec.client_id,
               "cached": rec.cached, "wall_s": round(rec.wall_s, 4)}
        row.update({f"knob.{k}": v for k, v in rec.knobs.items()})
        row.update({f"metric.{k}": v for k, v in rec.metrics.items()})
        return row

    def _adopt_existing_csv(self) -> None:
        """Resume-append: fold a pre-existing file's header/rows into ours."""
        if self._written_rows:
            return      # already writing this file (e.g. re-opened after close)
        if not (os.path.exists(self._csv_path)
                and os.path.getsize(self._csv_path) > 0):
            return
        with open(self._csv_path, newline="") as f:
            reader = csv.DictReader(f)
            for name in reader.fieldnames or []:
                if name.startswith("knob."):
                    self._knob_names.add(name[len("knob."):])
                elif name.startswith("metric."):
                    self._metric_names.add(name[len("metric."):])
            self._written_rows.extend(reader)

    def _open_writer(self, mode: str) -> None:
        if self._csv_file is not None:
            self._csv_file.close()
        self._csv_file = open(self._csv_path, mode, newline="")
        self._csv_writer = csv.DictWriter(
            self._csv_file, fieldnames=self._fieldnames(),
            extrasaction="ignore")

    def _append_csv(self, rec: ResultRecord) -> None:
        if self._csv_writer is None:
            os.makedirs(os.path.dirname(self._csv_path) or ".", exist_ok=True)
            self._adopt_existing_csv()
        new_knobs = set(rec.knobs) - self._knob_names
        new_metrics = set(rec.metrics) - self._metric_names
        if self._csv_writer is None or new_knobs or new_metrics:
            # widen the schema and rewrite everything written so far — a
            # frozen header would silently drop the new columns forever
            self._knob_names |= new_knobs
            self._metric_names |= new_metrics
            self._open_writer("w")
            self._csv_writer.writeheader()
            self._csv_writer.writerows(self._written_rows)
        row = self._flatten(rec)
        self._csv_writer.writerow(row)
        self._written_rows.append(row)
        self._csv_file.flush()

    def to_csv(self, path: str) -> None:
        if not self.records:
            return
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        knobs = sorted({k for r in self.records for k in r.knobs})
        metrics = sorted({k for r in self.records for k in r.metrics})
        fields = (list(self._BASE_FIELDS) + [f"knob.{k}" for k in knobs]
                  + [f"metric.{k}" for k in metrics])
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fields, extrasaction="ignore")
            w.writeheader()
            for r in self.records:
                w.writerow(self._flatten(r))

    def to_jsonl(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r.to_wire()) + "\n")

    # -- analysis ------------------------------------------------------------
    def ok_records(self) -> List[ResultRecord]:
        return [r for r in self.records if r.status == "ok"]

    def objective_matrix(self, keys: Sequence[str]) -> np.ndarray:
        return np.asarray([[r.metrics[k] for k in keys] for r in self.ok_records()])

    def pareto_front(self, keys: Sequence[str]) -> List[ResultRecord]:
        recs = self.ok_records()
        if not recs:
            return []
        pts = self.objective_matrix(keys)
        mask = nondominated_mask(pts)
        return [r for r, m in zip(recs, mask) if m]

    def close(self) -> None:
        if self._csv_file:
            self._csv_file.close()
            self._csv_file = self._csv_writer = None
