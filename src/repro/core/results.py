"""Result store + CSV export + Pareto utilities (paper §III "utility
functions such as saving the explored search space in CSV format")."""
from __future__ import annotations

import csv
import dataclasses
import json
import os
import threading
from typing import Any, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class ResultRecord:
    config_id: int
    arch: str
    shape: str
    knobs: Dict[str, Any]
    metrics: Dict[str, float]
    status: str = "ok"            # ok | failed | timeout
    client_id: int = -1
    cached: bool = False
    wall_s: float = 0.0

    def to_wire(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_wire(d: dict) -> "ResultRecord":
        return ResultRecord(**d)


def nondominated_mask(points: np.ndarray) -> np.ndarray:
    """points (N, M), minimisation.  True where no other point dominates."""
    n = len(points)
    mask = np.ones(n, bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominates = np.all(points <= points[i], axis=1) & np.any(points < points[i], axis=1)
        if np.any(dominates):
            mask[i] = False
    return mask


class ResultStore:
    def __init__(self, csv_path: Optional[str] = None):
        self.records: List[ResultRecord] = []
        self._csv_path = csv_path
        self._lock = threading.Lock()
        self._csv_file = None
        self._csv_writer = None

    def add(self, rec: ResultRecord) -> None:
        with self._lock:
            self.records.append(rec)
            if self._csv_path:
                self._append_csv(rec)

    # -- CSV ---------------------------------------------------------------
    def _fieldnames(self, rec: ResultRecord) -> List[str]:
        return (["config_id", "arch", "shape", "status", "client_id", "cached",
                 "wall_s"]
                + [f"knob.{k}" for k in sorted(rec.knobs)]
                + [f"metric.{k}" for k in sorted(rec.metrics)])

    def _flatten(self, rec: ResultRecord) -> Dict[str, Any]:
        row = {"config_id": rec.config_id, "arch": rec.arch, "shape": rec.shape,
               "status": rec.status, "client_id": rec.client_id,
               "cached": rec.cached, "wall_s": round(rec.wall_s, 4)}
        row.update({f"knob.{k}": v for k, v in rec.knobs.items()})
        row.update({f"metric.{k}": v for k, v in rec.metrics.items()})
        return row

    def _append_csv(self, rec: ResultRecord) -> None:
        new = not os.path.exists(self._csv_path) or os.path.getsize(self._csv_path) == 0
        if self._csv_writer is None:
            os.makedirs(os.path.dirname(self._csv_path) or ".", exist_ok=True)
            self._csv_file = open(self._csv_path, "a", newline="")
            self._csv_writer = csv.DictWriter(
                self._csv_file, fieldnames=self._fieldnames(rec), extrasaction="ignore")
            if new:
                self._csv_writer.writeheader()
        self._csv_writer.writerow(self._flatten(rec))
        self._csv_file.flush()

    def to_csv(self, path: str) -> None:
        if not self.records:
            return
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=self._fieldnames(self.records[0]),
                               extrasaction="ignore")
            w.writeheader()
            for r in self.records:
                w.writerow(self._flatten(r))

    def to_jsonl(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r.to_wire()) + "\n")

    # -- analysis ------------------------------------------------------------
    def ok_records(self) -> List[ResultRecord]:
        return [r for r in self.records if r.status == "ok"]

    def objective_matrix(self, keys: Sequence[str]) -> np.ndarray:
        return np.asarray([[r.metrics[k] for k in keys] for r in self.ok_records()])

    def pareto_front(self, keys: Sequence[str]) -> List[ResultRecord]:
        recs = self.ok_records()
        if not recs:
            return []
        pts = self.objective_matrix(keys)
        mask = nondominated_mask(pts)
        return [r for r, m in zip(recs, mask) if m]

    def close(self) -> None:
        if self._csv_file:
            self._csv_file.close()
            self._csv_file = self._csv_writer = None
