"""Host↔client communication (paper §III).

The paper uses ZMQ PUSH/PULL socket pairs ("each socket has a certain job"):
the host PUSHes testConfigs to each client's PULL socket and PULLs results
that clients PUSH back.  ``ZmqHostTransport``/``ZmqClientTransport`` keep that
protocol verbatim over TCP (the paper's SSH tunnelling removes the same-subnet
requirement on real fleets; out of scope in this container, see DESIGN.md §2).

``LoopbackPair`` is an in-process queue transport with the same interface so
unit tests and single-process exploration need no sockets.

Batch wire format
-----------------
Scalar mode sends one testConfig dict per message and gets one result dict
back — N configs cost 2N serialized messages plus N poll cycles.  The batched
fast path frames a whole chunk into **one** message per direction, and —
because every config/result in a chunk shares the same schema — transposes
the payload into *columns* so each key is serialized once per frame instead
of once per config:

    host → client   {"cmd": "batchc", "n": N,
                     "plain":  {"config_id": [...], "arch": [...], ...},
                     "nested": {"knobs": {knob_name: [N values], ...}}}
    client → host   same frame shape with result fields; "metrics" is the
                    nested column.  Batch results omit the knobs/arch/shape
                    echo — the host rehydrates them from its in-flight table,
                    so the dominant result payload is just the metric columns.

A chunk whose messages disagree on keys (e.g. nothing in common to
transpose) falls back to the row frame {"cmd": "batch", "items": [...]};
a *column* whose dict values disagree on sub-keys (e.g. ok metrics next to
{"error": ...}) falls back to a row list for that column only.
``push_many``/``pull_many`` do the (un)framing on top of the existing
``push``/``pull`` primitives, so every transport implementation — ZMQ and
loopback alike — gets batching without touching its socket code, and a
batched host interoperates with a scalar peer: ``pull_many`` transparently
wraps a lone scalar message into a one-element list, and a one-element
``push_many`` degenerates to a plain ``push``.

Codec layer
-----------
How a framed dict becomes wire bytes is pluggable per transport
(``codec="json"`` | ``"binary"`` | a ``repro.core.codec.Codec`` instance).
The binary codec packs a columnar frame's numeric columns as typed arrays —
see ``repro.core.codec``.  Every receive path decodes by sniffing
(``decode_wire``), so mixed fleets interoperate; client transports
additionally answer in the codec of the last frame they received, so a
binary host gets binary results back from a json-configured client.

Artifact verbs (fleet store)
----------------------------
The fleet-wide artifact store (``repro.core.fleet``) rides the same two
sockets as configs/results — clients have exactly one PUSH to the host and
one PULL from it — using five frame commands:

* ``ARTIFACT_QUERY`` (client→host) — "I miss fingerprint X in both my LRU
  and disk tiers; does the fleet have it?"  Carries ``addr`` (the content
  address, SHA-256 of ``repr((JConfig.identity(), cache_key))``) and ``fp``
  (``repr(cache_key)``, keying the host's residency map).
* ``ARTIFACT_PUT`` (both ways) — a pickled ``BuildResult`` blob (``blob``
  bytes), or a blob-less residency announcement (relay mode), or a
  ``status: "gone"`` apology when a relayed fetch finds nothing.
* ``ARTIFACT_CHUNK`` (both ways) — one slice of a large blob
  (``seq``/``n_chunks``); ``chunk_blob``/``ChunkAssembler`` split and
  reassemble, so multi-MB engines never occupy one giant frame.
* ``ARTIFACT_FETCH`` (host→client) — relay mode: "push me fingerprint X
  from your cache" (answered with a PUT, or a ``gone`` PUT).
* ``ARTIFACT_MISS`` (host→client) — the fleet has nothing: the asking
  client compiles, becoming the fingerprint's designated compiler.

``WireStats`` classifies these frames separately (``blob_*`` counters), so
the wire summary distinguishes artifact-blob traffic from config/result
traffic.
"""
from __future__ import annotations

import queue
from typing import Dict, List, Optional, Union

from repro.core.codec import (Codec, decode_wire, resolve_codec, sniff_codec)

# frame markers for a list-of-messages payload (host→client carries
# testConfigs, client→host carries results)
BATCH_CMD = "batch"          # row frame: {"items": [dict, ...]}
BATCH_COLS_CMD = "batchc"    # columnar frame: keys serialized once

# fleet artifact-store verbs (see module docstring + repro.core.fleet)
ARTIFACT_QUERY = "artifact_query"
ARTIFACT_PUT = "artifact_put"
ARTIFACT_CHUNK = "artifact_chunk"
ARTIFACT_FETCH = "artifact_fetch"
ARTIFACT_MISS = "artifact_miss"
ARTIFACT_CMDS = frozenset((ARTIFACT_QUERY, ARTIFACT_PUT, ARTIFACT_CHUNK,
                           ARTIFACT_FETCH, ARTIFACT_MISS))


def is_artifact_msg(msg) -> bool:
    """True for any fleet artifact-store frame."""
    return isinstance(msg, dict) and msg.get("cmd") in ARTIFACT_CMDS


def chunk_blob(base: dict, blob: bytes, chunk_bytes: int) -> List[dict]:
    """Frame ``blob`` onto ``base`` (an ARTIFACT_PUT-shaped dict): one PUT
    frame when it fits, else a run of ARTIFACT_CHUNK frames carrying the
    base's metadata plus ``seq``/``n_chunks``.  ``ChunkAssembler`` on the
    far side reconstructs the identical PUT frame."""
    if chunk_bytes <= 0 or len(blob) <= chunk_bytes:
        return [dict(base, cmd=ARTIFACT_PUT, blob=blob)]
    n = (len(blob) + chunk_bytes - 1) // chunk_bytes
    return [dict(base, cmd=ARTIFACT_CHUNK, seq=i, n_chunks=n,
                 blob=blob[i * chunk_bytes:(i + 1) * chunk_bytes])
            for i in range(n)]


class ChunkAssembler:
    """Reassemble ARTIFACT_CHUNK runs into the PUT frame they sliced.

    Keyed by (sender, addr) so interleaved streams from different peers —
    or for different artifacts — cannot corrupt each other.  ``feed``
    returns the completed PUT frame once every chunk arrived, else None.
    A restarted run for the same key (seq 0 seen again, or a changed
    n_chunks) discards the stale partial state.
    """

    def __init__(self):
        self._parts: Dict[tuple, List[Optional[bytes]]] = {}

    def feed(self, msg: dict) -> Optional[dict]:
        key = (msg.get("client_id"), msg.get("addr"))
        seq, n = msg.get("seq"), msg.get("n_chunks")
        if not isinstance(seq, int) or not isinstance(n, int) \
                or not 0 <= seq < n:
            return None                       # malformed: drop
        parts = self._parts.get(key)
        if parts is None or len(parts) != n or (seq == 0 and parts[0]
                                                is not None):
            parts = self._parts[key] = [None] * n
        blob = msg.get("blob")
        parts[seq] = bytes(blob) if isinstance(blob, (bytes, bytearray)) \
            else b""
        if any(p is None for p in parts):
            return None
        del self._parts[key]
        out = {k: v for k, v in msg.items() if k not in ("seq", "n_chunks")}
        out["cmd"] = ARTIFACT_PUT
        out["blob"] = b"".join(parts)
        return out


def frame_batch(msgs: List[dict]) -> dict:
    """Frame a chunk, transposing to columns when the schema is uniform."""
    keys = msgs[0].keys()
    if any(m.keys() != keys for m in msgs[1:]):
        return {"cmd": BATCH_CMD, "items": list(msgs)}
    plain: Dict[str, list] = {}
    nested: Dict[str, Dict[str, list]] = {}
    for k in keys:
        vals = [m[k] for m in msgs]
        if isinstance(vals[0], dict):
            sub = vals[0].keys()
            if all(isinstance(v, dict) and v.keys() == sub for v in vals[1:]):
                nested[k] = {s: [v[s] for v in vals] for s in sub}
                continue
        plain[k] = vals
    return {"cmd": BATCH_COLS_CMD, "n": len(msgs),
            "plain": plain, "nested": nested}


def unframe_batch(msg: Optional[dict]) -> List[dict]:
    """Normalise a pulled message to a list of payload dicts.

    Frame-level sidecar fields (currently ``cache_info``, the artifact-cache
    summary a client attaches once per result frame) are re-attached to the
    *last* payload dict, so per-frame metadata survives the row/column
    transpose without being duplicated onto every result.
    """
    if msg is None:
        return []
    cmd = msg.get("cmd")
    if cmd == BATCH_CMD:
        items: List[dict] = list(msg["items"])
    elif cmd == BATCH_COLS_CMD:
        items = [{} for _ in range(msg["n"])]
        for k, col in msg["plain"].items():
            for it, v in zip(items, col):
                it[k] = v
        for k, sub in msg["nested"].items():
            if not sub:               # a column of uniformly-empty dicts
                for it in items:
                    it[k] = {}
                continue
            rebuilt = [dict(zip(sub.keys(), row)) for row in zip(*sub.values())]
            for it, v in zip(items, rebuilt):
                it[k] = v
    else:
        return [msg]
    sidecar = msg.get("cache_info")
    if sidecar is not None and items:
        items[-1] = dict(items[-1], cache_info=sidecar)
    return items


class WireStats:
    """Post-codec bytes/frames actually put on the wire, per peer.

    Host transports count outbound bytes per client and inbound bytes per
    reporting client (attributed from the decoded frame's ``client_id``
    field/column).  The host attaches ``wire_summary`` to the scheduler so
    ``DispatchScheduler.stats()`` — and the ``progress=True`` line — can
    show what each codec really costs on the wire.

    Frames are additionally accounted *per class*: artifact-store frames
    (``ARTIFACT_*`` commands — dominated by pickled ``BuildResult`` blobs)
    land in the ``blob_*`` counters as well as the totals, so the summary
    separates what the fleet cache moves from what dispatch/results move.
    """

    def __init__(self):
        self.out_bytes: Dict[int, int] = {}
        self.out_frames: Dict[int, int] = {}
        self.in_bytes: Dict[int, int] = {}
        self.in_frames: Dict[int, int] = {}
        # artifact-class subset of the totals above
        self.blob_out_bytes: Dict[int, int] = {}
        self.blob_out_frames: Dict[int, int] = {}
        self.blob_in_bytes: Dict[int, int] = {}
        self.blob_in_frames: Dict[int, int] = {}

    def sent(self, client_id: int, nbytes: int,
             msg: Optional[dict] = None) -> None:
        self.out_bytes[client_id] = self.out_bytes.get(client_id, 0) + nbytes
        self.out_frames[client_id] = self.out_frames.get(client_id, 0) + 1
        if is_artifact_msg(msg):
            self.blob_out_bytes[client_id] = \
                self.blob_out_bytes.get(client_id, 0) + nbytes
            self.blob_out_frames[client_id] = \
                self.blob_out_frames.get(client_id, 0) + 1

    def received(self, msg: Optional[dict], nbytes: int) -> None:
        """Attribute an inbound frame to its reporting client (-1 unknown)."""
        cid = -1
        if isinstance(msg, dict):
            v = msg.get("client_id")
            if v is None and msg.get("cmd") == BATCH_COLS_CMD:
                col = msg.get("plain", {}).get("client_id")
                v = col[0] if col else None
            elif v is None and msg.get("cmd") == BATCH_CMD:
                items = msg.get("items")
                v = items[0].get("client_id") if items else None
            if isinstance(v, int):
                cid = v
        self.in_bytes[cid] = self.in_bytes.get(cid, 0) + nbytes
        self.in_frames[cid] = self.in_frames.get(cid, 0) + 1
        if is_artifact_msg(msg):
            self.blob_in_bytes[cid] = self.blob_in_bytes.get(cid, 0) + nbytes
            self.blob_in_frames[cid] = self.blob_in_frames.get(cid, 0) + 1

    def summary(self) -> Dict:
        per_client = {}
        for cid in sorted(set(self.out_bytes) | set(self.in_bytes)):
            row = {"out_kb": round(self.out_bytes.get(cid, 0) / 1e3, 2),
                   "out_frames": self.out_frames.get(cid, 0),
                   "in_kb": round(self.in_bytes.get(cid, 0) / 1e3, 2),
                   "in_frames": self.in_frames.get(cid, 0)}
            if self.blob_out_bytes.get(cid) or self.blob_in_bytes.get(cid):
                row["blob_out_kb"] = round(
                    self.blob_out_bytes.get(cid, 0) / 1e3, 2)
                row["blob_in_kb"] = round(
                    self.blob_in_bytes.get(cid, 0) / 1e3, 2)
            per_client[cid] = row
        s = {
            "wire_out_mb": round(sum(self.out_bytes.values()) / 1e6, 6),
            "wire_in_mb": round(sum(self.in_bytes.values()) / 1e6, 6),
            "wire_out_frames": sum(self.out_frames.values()),
            "wire_in_frames": sum(self.in_frames.values()),
            "wire_per_client": per_client,
        }
        if self.blob_out_bytes or self.blob_in_bytes:
            s["wire_blob_out_mb"] = round(
                sum(self.blob_out_bytes.values()) / 1e6, 6)
            s["wire_blob_in_mb"] = round(
                sum(self.blob_in_bytes.values()) / 1e6, 6)
            s["wire_blob_frames"] = (sum(self.blob_out_frames.values())
                                     + sum(self.blob_in_frames.values()))
        return s


class HostTransport:
    def push(self, client_id: int, msg: dict) -> None:
        raise NotImplementedError

    def pull(self, timeout_s: float) -> Optional[dict]:
        raise NotImplementedError

    def _wire(self) -> WireStats:
        w = getattr(self, "wire", None)
        if w is None:
            w = self.wire = WireStats()
        return w

    def wire_summary(self) -> Dict:
        """Codec + bytes-on-wire stats; {} until something was counted."""
        w = getattr(self, "wire", None)
        if w is None:
            return {}
        s = w.summary()
        codec = getattr(self, "_codec", None)
        if codec is not None:
            s["codec"] = codec.name
        return s

    def push_many(self, client_id: int, msgs: List[dict]) -> None:
        """Ship a whole chunk of testConfigs as one framed message."""
        if len(msgs) == 1:
            self.push(client_id, msgs[0])
        elif msgs:
            self.push(client_id, frame_batch(msgs))

    def pull_many(self, timeout_s: float) -> List[dict]:
        """Pull one message and unframe it: 0, 1, or many results."""
        return unframe_batch(self.pull(timeout_s))

    def client_ids(self) -> List[int]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class ClientTransport:
    # wire-codec negotiation: answer in the codec the host last spoke
    _codec: Codec = resolve_codec("json")
    _peer_codec: Optional[Codec] = None

    def _note_wire(self, data) -> None:
        self._peer_codec = resolve_codec(sniff_codec(data))

    def _wire_codec(self) -> Codec:
        return self._peer_codec or self._codec

    def pull(self, timeout_s: float) -> Optional[dict]:
        raise NotImplementedError

    def push(self, msg: dict) -> None:
        raise NotImplementedError

    def push_many(self, msgs: List[dict],
                  extra: Optional[dict] = None) -> None:
        """Ship a whole batch of results as one framed message.

        ``extra`` keys ride on the frame dict itself (once per frame, not
        per result) and are re-attached by ``unframe_batch`` on the far
        side — how a client reports ``cache_info`` per chunk reply.
        """
        if len(msgs) == 1 and not extra:
            self.push(msgs[0])
        elif msgs:
            frame = frame_batch(msgs)
            if extra:
                frame.update(extra)
            self.push(frame)

    def pull_many(self, timeout_s: float) -> List[dict]:
        return unframe_batch(self.pull(timeout_s))

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# ZMQ (paper-faithful)
# ---------------------------------------------------------------------------


class ZmqHostTransport(HostTransport):
    """Host: one PUSH socket per client + one bound PULL for results.

    ``zmq.Context.instance()`` is process-global, so by default close() only
    closes this transport's sockets and leaves the shared context alone;
    pass ``own_ctx=True`` for a private context that close() terminates.
    close() is idempotent and linger-free either way.
    """

    def __init__(self, result_bind: str, client_endpoints: Dict[int, str],
                 codec: Union[str, Codec] = "json", own_ctx: bool = False):
        import zmq

        self._codec = resolve_codec(codec)
        self._own_ctx = own_ctx
        self._ctx = zmq.Context() if own_ctx else zmq.Context.instance()
        self._closed = False
        self._pull = self._ctx.socket(zmq.PULL)
        self._pull.setsockopt(zmq.LINGER, 0)
        self._pull.bind(result_bind)
        self._push = {}
        for cid, ep in client_endpoints.items():
            s = self._ctx.socket(zmq.PUSH)
            s.setsockopt(zmq.LINGER, 0)
            s.connect(ep)
            self._push[cid] = s

    def push(self, client_id: int, msg: dict) -> None:
        data = self._codec.encode(msg)
        self._wire().sent(client_id, len(data), msg)
        self._push[client_id].send(data)

    def pull(self, timeout_s: float) -> Optional[dict]:
        import zmq

        if self._pull.poll(int(timeout_s * 1000), zmq.POLLIN):
            data = self._pull.recv()
            msg = decode_wire(data)
            self._wire().received(msg, len(data))
            return msg
        return None

    def client_ids(self) -> List[int]:
        return sorted(self._push)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for s in self._push.values():
            s.close(0)
        self._pull.close(0)
        if self._own_ctx:
            self._ctx.term()


class ZmqClientTransport(ClientTransport):
    """Client: bound PULL for configs + PUSH connected to the host.

    Same context/teardown policy as ``ZmqHostTransport``.
    """

    def __init__(self, config_bind: str, result_endpoint: str,
                 codec: Union[str, Codec] = "json", own_ctx: bool = False):
        import zmq

        self._codec = resolve_codec(codec)
        self._peer_codec = None
        self._own_ctx = own_ctx
        self._ctx = zmq.Context() if own_ctx else zmq.Context.instance()
        self._closed = False
        self._pull = self._ctx.socket(zmq.PULL)
        self._pull.setsockopt(zmq.LINGER, 0)
        self._pull.bind(config_bind)
        self._push = self._ctx.socket(zmq.PUSH)
        self._push.setsockopt(zmq.LINGER, 0)
        self._push.connect(result_endpoint)

    def pull(self, timeout_s: float) -> Optional[dict]:
        import zmq

        if self._pull.poll(int(timeout_s * 1000), zmq.POLLIN):
            data = self._pull.recv()
            self._note_wire(data)
            return decode_wire(data)
        return None

    def push(self, msg: dict) -> None:
        self._push.send(self._wire_codec().encode(msg))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._pull.close(0)
        self._push.close(0)
        if self._own_ctx:
            self._ctx.term()


# ---------------------------------------------------------------------------
# In-process loopback (tests / single-process exploration)
# ---------------------------------------------------------------------------


class LoopbackPair:
    """Queues shared by a LoopbackHost and its LoopbackClients."""

    def __init__(self, n_clients: int, codec: Union[str, Codec] = "json"):
        self.to_client = {i: queue.Queue() for i in range(n_clients)}
        self.to_host: "queue.Queue" = queue.Queue()
        self.codec = resolve_codec(codec)

    def host(self, codec: Union[str, Codec, None] = None
             ) -> "LoopbackHostTransport":
        return LoopbackHostTransport(
            self, self.codec if codec is None else resolve_codec(codec))

    def client(self, client_id: int, codec: Union[str, Codec, None] = None
               ) -> "LoopbackClientTransport":
        return LoopbackClientTransport(
            self, client_id,
            self.codec if codec is None else resolve_codec(codec))


class LoopbackHostTransport(HostTransport):
    def __init__(self, pair: LoopbackPair, codec: Optional[Codec] = None):
        self._pair = pair
        self._codec = codec or pair.codec

    def push(self, client_id: int, msg: dict) -> None:
        # round-trip through the codec to keep wire-format parity with ZMQ
        data = self._codec.encode(msg)
        self._wire().sent(client_id, len(data), msg)
        self._pair.to_client[client_id].put(data)

    def pull(self, timeout_s: float) -> Optional[dict]:
        try:
            data = self._pair.to_host.get(timeout=timeout_s)
        except queue.Empty:
            return None
        msg = decode_wire(data)
        self._wire().received(msg, len(data))
        return msg

    def client_ids(self) -> List[int]:
        return sorted(self._pair.to_client)


class LoopbackClientTransport(ClientTransport):
    def __init__(self, pair: LoopbackPair, client_id: int,
                 codec: Optional[Codec] = None):
        self._pair = pair
        self._cid = client_id
        self._codec = codec or pair.codec
        self._peer_codec = None

    def pull(self, timeout_s: float) -> Optional[dict]:
        try:
            data = self._pair.to_client[self._cid].get(timeout=timeout_s)
        except queue.Empty:
            return None
        self._note_wire(data)
        return decode_wire(data)

    def push(self, msg: dict) -> None:
        self._pair.to_host.put(self._wire_codec().encode(msg))
