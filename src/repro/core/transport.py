"""Host↔client communication (paper §III).

The paper uses ZMQ PUSH/PULL socket pairs ("each socket has a certain job"):
the host PUSHes testConfigs to each client's PULL socket and PULLs results
that clients PUSH back.  ``ZmqHostTransport``/``ZmqClientTransport`` keep that
protocol verbatim over TCP (the paper's SSH tunnelling removes the same-subnet
requirement on real fleets; out of scope in this container, see DESIGN.md §2).

``LoopbackPair`` is an in-process queue transport with the same interface so
unit tests and single-process exploration need no sockets.
"""
from __future__ import annotations

import json
import queue
import threading
from typing import Dict, List, Optional


class HostTransport:
    def push(self, client_id: int, msg: dict) -> None:
        raise NotImplementedError

    def pull(self, timeout_s: float) -> Optional[dict]:
        raise NotImplementedError

    def client_ids(self) -> List[int]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class ClientTransport:
    def pull(self, timeout_s: float) -> Optional[dict]:
        raise NotImplementedError

    def push(self, msg: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# ZMQ (paper-faithful)
# ---------------------------------------------------------------------------


class ZmqHostTransport(HostTransport):
    """Host: one PUSH socket per client + one bound PULL for results."""

    def __init__(self, result_bind: str, client_endpoints: Dict[int, str]):
        import zmq

        self._ctx = zmq.Context.instance()
        self._pull = self._ctx.socket(zmq.PULL)
        self._pull.bind(result_bind)
        self._push = {}
        for cid, ep in client_endpoints.items():
            s = self._ctx.socket(zmq.PUSH)
            s.setsockopt(zmq.LINGER, 0)
            s.connect(ep)
            self._push[cid] = s

    def push(self, client_id: int, msg: dict) -> None:
        self._push[client_id].send_json(msg)

    def pull(self, timeout_s: float) -> Optional[dict]:
        import zmq

        if self._pull.poll(int(timeout_s * 1000), zmq.POLLIN):
            return self._pull.recv_json()
        return None

    def client_ids(self) -> List[int]:
        return sorted(self._push)

    def close(self) -> None:
        for s in self._push.values():
            s.close(0)
        self._pull.close(0)


class ZmqClientTransport(ClientTransport):
    """Client: bound PULL for configs + PUSH connected to the host."""

    def __init__(self, config_bind: str, result_endpoint: str):
        import zmq

        self._ctx = zmq.Context.instance()
        self._pull = self._ctx.socket(zmq.PULL)
        self._pull.bind(config_bind)
        self._push = self._ctx.socket(zmq.PUSH)
        self._push.setsockopt(zmq.LINGER, 0)
        self._push.connect(result_endpoint)

    def pull(self, timeout_s: float) -> Optional[dict]:
        import zmq

        if self._pull.poll(int(timeout_s * 1000), zmq.POLLIN):
            return self._pull.recv_json()
        return None

    def push(self, msg: dict) -> None:
        self._push.send_json(msg)

    def close(self) -> None:
        self._pull.close(0)
        self._push.close(0)


# ---------------------------------------------------------------------------
# In-process loopback (tests / single-process exploration)
# ---------------------------------------------------------------------------


class LoopbackPair:
    """Queues shared by a LoopbackHost and its LoopbackClients."""

    def __init__(self, n_clients: int):
        self.to_client = {i: queue.Queue() for i in range(n_clients)}
        self.to_host: "queue.Queue" = queue.Queue()

    def host(self) -> "LoopbackHostTransport":
        return LoopbackHostTransport(self)

    def client(self, client_id: int) -> "LoopbackClientTransport":
        return LoopbackClientTransport(self, client_id)


class LoopbackHostTransport(HostTransport):
    def __init__(self, pair: LoopbackPair):
        self._pair = pair

    def push(self, client_id: int, msg: dict) -> None:
        # round-trip through JSON to keep wire-format parity with ZMQ
        self._pair.to_client[client_id].put(json.dumps(msg))

    def pull(self, timeout_s: float) -> Optional[dict]:
        try:
            return json.loads(self._pair.to_host.get(timeout=timeout_s))
        except queue.Empty:
            return None

    def client_ids(self) -> List[int]:
        return sorted(self._pair.to_client)


class LoopbackClientTransport(ClientTransport):
    def __init__(self, pair: LoopbackPair, client_id: int):
        self._pair = pair
        self._cid = client_id

    def pull(self, timeout_s: float) -> Optional[dict]:
        try:
            return json.loads(self._pair.to_client[self._cid].get(timeout=timeout_s))
        except queue.Empty:
            return None

    def push(self, msg: dict) -> None:
        self._pair.to_host.put(json.dumps(msg))
