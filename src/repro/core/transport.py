"""Host↔client communication (paper §III).

The paper uses ZMQ PUSH/PULL socket pairs ("each socket has a certain job"):
the host PUSHes testConfigs to each client's PULL socket and PULLs results
that clients PUSH back.  ``ZmqHostTransport``/``ZmqClientTransport`` keep that
protocol verbatim over TCP (the paper's SSH tunnelling removes the same-subnet
requirement on real fleets; out of scope in this container, see DESIGN.md §2).

``LoopbackPair`` is an in-process queue transport with the same interface so
unit tests and single-process exploration need no sockets.

Batch wire format
-----------------
Scalar mode sends one testConfig dict per message and gets one result dict
back — N configs cost 2N serialized messages plus N poll cycles.  The batched
fast path frames a whole chunk into **one** message per direction, and —
because every config/result in a chunk shares the same schema — transposes
the payload into *columns* so each key is serialized once per frame instead
of once per config:

    host → client   {"cmd": "batchc", "n": N,
                     "plain":  {"config_id": [...], "arch": [...], ...},
                     "nested": {"knobs": {knob_name: [N values], ...}}}
    client → host   same frame shape with result fields; "metrics" is the
                    nested column.  Batch results omit the knobs/arch/shape
                    echo — the host rehydrates them from its in-flight table,
                    so the dominant result payload is just the metric columns.

A chunk whose messages disagree on keys (e.g. nothing in common to
transpose) falls back to the row frame {"cmd": "batch", "items": [...]};
a *column* whose dict values disagree on sub-keys (e.g. ok metrics next to
{"error": ...}) falls back to a row list for that column only.
``push_many``/``pull_many`` do the (un)framing on top of the existing
``push``/``pull`` primitives, so every transport implementation — ZMQ and
loopback alike — gets batching without touching its socket code, and a
batched host interoperates with a scalar peer: ``pull_many`` transparently
wraps a lone scalar message into a one-element list, and a one-element
``push_many`` degenerates to a plain ``push``.
"""
from __future__ import annotations

import json
import queue
import threading
from typing import Dict, List, Optional

# frame markers for a list-of-messages payload (host→client carries
# testConfigs, client→host carries results)
BATCH_CMD = "batch"          # row frame: {"items": [dict, ...]}
BATCH_COLS_CMD = "batchc"    # columnar frame: keys serialized once


def frame_batch(msgs: List[dict]) -> dict:
    """Frame a chunk, transposing to columns when the schema is uniform."""
    keys = msgs[0].keys()
    if any(m.keys() != keys for m in msgs[1:]):
        return {"cmd": BATCH_CMD, "items": list(msgs)}
    plain: Dict[str, list] = {}
    nested: Dict[str, Dict[str, list]] = {}
    for k in keys:
        vals = [m[k] for m in msgs]
        if isinstance(vals[0], dict):
            sub = vals[0].keys()
            if all(isinstance(v, dict) and v.keys() == sub for v in vals[1:]):
                nested[k] = {s: [v[s] for v in vals] for s in sub}
                continue
        plain[k] = vals
    return {"cmd": BATCH_COLS_CMD, "n": len(msgs),
            "plain": plain, "nested": nested}


def unframe_batch(msg: Optional[dict]) -> List[dict]:
    """Normalise a pulled message to a list of payload dicts."""
    if msg is None:
        return []
    cmd = msg.get("cmd")
    if cmd == BATCH_CMD:
        return list(msg["items"])
    if cmd == BATCH_COLS_CMD:
        items: List[dict] = [{} for _ in range(msg["n"])]
        for k, col in msg["plain"].items():
            for it, v in zip(items, col):
                it[k] = v
        for k, sub in msg["nested"].items():
            if not sub:               # a column of uniformly-empty dicts
                for it in items:
                    it[k] = {}
                continue
            rebuilt = [dict(zip(sub.keys(), row)) for row in zip(*sub.values())]
            for it, v in zip(items, rebuilt):
                it[k] = v
        return items
    return [msg]


class HostTransport:
    def push(self, client_id: int, msg: dict) -> None:
        raise NotImplementedError

    def pull(self, timeout_s: float) -> Optional[dict]:
        raise NotImplementedError

    def push_many(self, client_id: int, msgs: List[dict]) -> None:
        """Ship a whole chunk of testConfigs as one framed message."""
        if len(msgs) == 1:
            self.push(client_id, msgs[0])
        elif msgs:
            self.push(client_id, frame_batch(msgs))

    def pull_many(self, timeout_s: float) -> List[dict]:
        """Pull one message and unframe it: 0, 1, or many results."""
        return unframe_batch(self.pull(timeout_s))

    def client_ids(self) -> List[int]:
        raise NotImplementedError

    def close(self) -> None:
        pass


class ClientTransport:
    def pull(self, timeout_s: float) -> Optional[dict]:
        raise NotImplementedError

    def push(self, msg: dict) -> None:
        raise NotImplementedError

    def push_many(self, msgs: List[dict]) -> None:
        """Ship a whole batch of results as one framed message."""
        if len(msgs) == 1:
            self.push(msgs[0])
        elif msgs:
            self.push(frame_batch(msgs))

    def pull_many(self, timeout_s: float) -> List[dict]:
        return unframe_batch(self.pull(timeout_s))

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# ZMQ (paper-faithful)
# ---------------------------------------------------------------------------


class ZmqHostTransport(HostTransport):
    """Host: one PUSH socket per client + one bound PULL for results."""

    def __init__(self, result_bind: str, client_endpoints: Dict[int, str]):
        import zmq

        self._ctx = zmq.Context.instance()
        self._pull = self._ctx.socket(zmq.PULL)
        self._pull.bind(result_bind)
        self._push = {}
        for cid, ep in client_endpoints.items():
            s = self._ctx.socket(zmq.PUSH)
            s.setsockopt(zmq.LINGER, 0)
            s.connect(ep)
            self._push[cid] = s

    def push(self, client_id: int, msg: dict) -> None:
        self._push[client_id].send_json(msg)

    def pull(self, timeout_s: float) -> Optional[dict]:
        import zmq

        if self._pull.poll(int(timeout_s * 1000), zmq.POLLIN):
            return self._pull.recv_json()
        return None

    def client_ids(self) -> List[int]:
        return sorted(self._push)

    def close(self) -> None:
        for s in self._push.values():
            s.close(0)
        self._pull.close(0)


class ZmqClientTransport(ClientTransport):
    """Client: bound PULL for configs + PUSH connected to the host."""

    def __init__(self, config_bind: str, result_endpoint: str):
        import zmq

        self._ctx = zmq.Context.instance()
        self._pull = self._ctx.socket(zmq.PULL)
        self._pull.bind(config_bind)
        self._push = self._ctx.socket(zmq.PUSH)
        self._push.setsockopt(zmq.LINGER, 0)
        self._push.connect(result_endpoint)

    def pull(self, timeout_s: float) -> Optional[dict]:
        import zmq

        if self._pull.poll(int(timeout_s * 1000), zmq.POLLIN):
            return self._pull.recv_json()
        return None

    def push(self, msg: dict) -> None:
        self._push.send_json(msg)

    def close(self) -> None:
        self._pull.close(0)
        self._push.close(0)


# ---------------------------------------------------------------------------
# In-process loopback (tests / single-process exploration)
# ---------------------------------------------------------------------------


class LoopbackPair:
    """Queues shared by a LoopbackHost and its LoopbackClients."""

    def __init__(self, n_clients: int):
        self.to_client = {i: queue.Queue() for i in range(n_clients)}
        self.to_host: "queue.Queue" = queue.Queue()

    def host(self) -> "LoopbackHostTransport":
        return LoopbackHostTransport(self)

    def client(self, client_id: int) -> "LoopbackClientTransport":
        return LoopbackClientTransport(self, client_id)


class LoopbackHostTransport(HostTransport):
    def __init__(self, pair: LoopbackPair):
        self._pair = pair

    def push(self, client_id: int, msg: dict) -> None:
        # round-trip through JSON to keep wire-format parity with ZMQ
        self._pair.to_client[client_id].put(json.dumps(msg))

    def pull(self, timeout_s: float) -> Optional[dict]:
        try:
            return json.loads(self._pair.to_host.get(timeout=timeout_s))
        except queue.Empty:
            return None

    def client_ids(self) -> List[int]:
        return sorted(self._pair.to_client)


class LoopbackClientTransport(ClientTransport):
    def __init__(self, pair: LoopbackPair, client_id: int):
        self._pair = pair
        self._cid = client_id

    def pull(self, timeout_s: float) -> Optional[dict]:
        try:
            return json.loads(self._pair.to_client[self._cid].get(timeout=timeout_s))
        except queue.Empty:
            return None

    def push(self, msg: dict) -> None:
        self._pair.to_host.put(json.dumps(msg))
