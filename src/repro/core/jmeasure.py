"""JMeasure — metric measurement (paper §III).

Abstract exactly as in the paper so users plug custom measurement functions;
the bundled implementations are the TPU adaptation of JTime / JPower /
JMemory.  On Jetson these read wall-clocks and INA power rails; on this
CPU-only container they evaluate the calibrated analytic model over the
compiled XLA artifact (DESIGN.md §2).  On a real TPU fleet the same ABC takes
wall-clock / power-rail plugins without touching JHost/JClient/search code.
"""
from __future__ import annotations

import abc
from typing import Dict

import numpy as np

from repro.roofline.analysis import Artifact
from repro.roofline.hw import HwModel, HwModelBatch


class JMeasure(abc.ABC):
    """One metric.  ``measure`` maps (artifact, hw model, workload meta) → dict.

    ``measure_batch`` is the vectorized form used by the batched fast path:
    one artifact swept over N hardware variants, returning ``(N,)`` arrays
    per metric key.  The base implementation falls back to N scalar
    ``measure`` calls, so custom user measures work in batch mode unchanged;
    the bundled measures override it with one-shot numpy sweeps that are
    bit-identical to the scalar path.
    """

    name: str = "measure"

    @abc.abstractmethod
    def measure(self, art: Artifact, hw: HwModel, meta: Dict) -> Dict[str, float]:
        ...

    def measure_batch(self, art: Artifact, hwb: HwModelBatch,
                      meta: Dict) -> Dict[str, np.ndarray]:
        rows = [self.measure(art, hw, meta) for hw in hwb.iter_models()]
        keys = rows[0].keys() if rows else ()
        return {k: np.asarray([r[k] for r in rows]) for k in keys}


class JTime(JMeasure):
    """Roofline-estimated execution time.

    For generation workloads (the paper's Llama/LLaVA experiments) meta may
    carry ``n_decode_tokens`` and a separate decode artifact; total time is
    t_prefill + n_tokens · t_decode, matching the paper's end-to-end latency.
    """

    name = "time"

    def measure(self, art: Artifact, hw: HwModel, meta: Dict) -> Dict[str, float]:
        terms = hw.roofline_terms(art.global_flops,
                                  art.effective_bytes_per_device * art.n_devices,
                                  art.wire_bytes_per_device * art.n_devices)
        out = {"time_s": terms["step_time_s"],
               "compute_s": terms["compute_s"],
               "memory_s": terms["memory_s"],
               "collective_s": terms["collective_s"],
               "bottleneck": terms["dominant"]}
        dec = meta.get("decode_artifact")
        if dec is not None:
            n_tok = int(meta.get("n_decode_tokens", 0))
            dterms = hw.roofline_terms(dec.global_flops,
                                       dec.effective_bytes_per_device * dec.n_devices,
                                       dec.wire_bytes_per_device * dec.n_devices)
            out["decode_step_s"] = dterms["step_time_s"]
            out["time_s"] = out["time_s"] + n_tok * dterms["step_time_s"]
        n_steps = int(meta.get("n_steps", 0))
        if n_steps:
            out["total_s"] = out["time_s"] * n_steps
        return out

    def measure_batch(self, art: Artifact, hwb: HwModelBatch,
                      meta: Dict) -> Dict[str, np.ndarray]:
        terms = hwb.roofline_terms_batch(
            art.global_flops,
            art.effective_bytes_per_device * art.n_devices,
            art.wire_bytes_per_device * art.n_devices)
        out = {"time_s": terms["step_time_s"],
               "compute_s": terms["compute_s"],
               "memory_s": terms["memory_s"],
               "collective_s": terms["collective_s"],
               "bottleneck": terms["dominant"]}
        dec = meta.get("decode_artifact")
        if dec is not None:
            n_tok = int(meta.get("n_decode_tokens", 0))
            dterms = hwb.roofline_terms_batch(
                dec.global_flops,
                dec.effective_bytes_per_device * dec.n_devices,
                dec.wire_bytes_per_device * dec.n_devices)
            out["decode_step_s"] = dterms["step_time_s"]
            out["time_s"] = out["time_s"] + n_tok * dterms["step_time_s"]
        n_steps = int(meta.get("n_steps", 0))
        if n_steps:
            out["total_s"] = out["time_s"] * n_steps
        return out


class JPower(JMeasure):
    name = "power"

    def measure(self, art: Artifact, hw: HwModel, meta: Dict) -> Dict[str, float]:
        terms = hw.roofline_terms(art.global_flops,
                                  art.effective_bytes_per_device * art.n_devices,
                                  art.wire_bytes_per_device * art.n_devices)
        t = terms["step_time_s"]
        p = hw.power_w(art.global_flops, art.effective_bytes_per_device * art.n_devices, t)
        out = {"power_w": p, "energy_j": p * hw.n_chips * t}
        dec = meta.get("decode_artifact")
        if dec is not None:
            n_tok = int(meta.get("n_decode_tokens", 0))
            dterms = hw.roofline_terms(dec.global_flops,
                                       dec.effective_bytes_per_device * dec.n_devices,
                                       dec.wire_bytes_per_device * dec.n_devices)
            td = dterms["step_time_s"]
            pd = hw.power_w(dec.global_flops,
                            dec.effective_bytes_per_device * dec.n_devices, td)
            tot_t = t + n_tok * td
            tot_e = p * hw.n_chips * t + pd * hw.n_chips * n_tok * td
            out = {"power_w": tot_e / (hw.n_chips * tot_t), "energy_j": tot_e}
        return out

    def measure_batch(self, art: Artifact, hwb: HwModelBatch,
                      meta: Dict) -> Dict[str, np.ndarray]:
        flops = art.global_flops
        hbm = art.effective_bytes_per_device * art.n_devices
        wire = art.wire_bytes_per_device * art.n_devices
        terms = hwb.roofline_terms_batch(flops, hbm, wire)
        t = terms["step_time_s"]
        p = hwb.power_w_batch(flops, hbm, t)
        out = {"power_w": p, "energy_j": p * hwb.n_chips * t}
        dec = meta.get("decode_artifact")
        if dec is not None:
            n_tok = int(meta.get("n_decode_tokens", 0))
            dflops = dec.global_flops
            dhbm = dec.effective_bytes_per_device * dec.n_devices
            dwire = dec.wire_bytes_per_device * dec.n_devices
            dterms = hwb.roofline_terms_batch(dflops, dhbm, dwire)
            td = dterms["step_time_s"]
            pd = hwb.power_w_batch(dflops, dhbm, td)
            tot_t = t + n_tok * td
            tot_e = p * hwb.n_chips * t + pd * hwb.n_chips * n_tok * td
            if np.any(tot_t == 0.0):
                # scalar-path parity: the scalar normalisation raises here
                # (status 'failed') instead of silently emitting NaN
                raise ZeroDivisionError("zero total time in power measurement")
            out = {"power_w": tot_e / (hwb.n_chips * tot_t), "energy_j": tot_e}
        return out


class JMemory(JMeasure):
    name = "memory"

    HBM_BYTES = 16 * 1024 ** 3  # v5e per-chip HBM

    def measure(self, art: Artifact, hw: HwModel, meta: Dict) -> Dict[str, float]:
        peak = art.peak_memory_per_device
        dec = meta.get("decode_artifact")
        if dec is not None:
            peak = max(peak, dec.peak_memory_per_device)
        return {"mem_bytes": float(peak),
                "fits_hbm": float(peak <= self.HBM_BYTES)}

    def measure_batch(self, art: Artifact, hwb: HwModelBatch,
                      meta: Dict) -> Dict[str, np.ndarray]:
        # hw-knob independent: the same artifact footprint for every variant
        peak = art.peak_memory_per_device
        dec = meta.get("decode_artifact")
        if dec is not None:
            peak = max(peak, dec.peak_memory_per_device)
        n = len(hwb)
        return {"mem_bytes": np.full(n, float(peak)),
                "fits_hbm": np.full(n, float(peak <= self.HBM_BYTES))}


DEFAULT_MEASURES = (JTime(), JPower(), JMemory())
