"""Fleet-wide artifact store — cross-client sharing of compiled artifacts.

PR 4's persistent cache is per-client: two boards with the same
``JConfig.identity()`` still each compile every fingerprint once, so fleet
compile cost scales with *placement* instead of with *unique work*.  This
module promotes the content-addressed disk tier to a host-mediated fleet
store — the XLA persistent-compilation-cache idea lifted to a multi-board
fleet:

* A client that misses both its LRU and its disk tier pushes an
  ``ARTIFACT_QUERY`` up its existing result socket (``addr`` = SHA-256 of
  ``repr((JConfig.identity(), cache_key))``, the same address the disk
  tier uses) and blocks briefly for the reply.
* ``FleetArtifactStore`` lives in the host loop (``JHost.explore``
  intercepts artifact frames before scheduler bookkeeping) and keeps the
  fleet-global generalization of the per-slot ``CacheShadow``: a residency
  map ``addr -> {client_ids}`` that also covers each client's *disk* tier
  (shadows are LRU-bounded; residency is not) plus, in ``serve`` mode, a
  byte-budgeted LRU blob cache of pickled ``BuildResult``s.
* ``mode="serve"`` — clients announce every fresh compile with the blob
  attached; the host caches it and serves later queries directly (one
  client→host upload per unique fingerprint, then host→client downloads).
* ``mode="relay"`` — clients announce residency only (no upload); on a
  query the host relays an ``ARTIFACT_FETCH`` to a resident peer and
  forwards the returned blob to the waiters without retaining it (host
  memory stays O(residency map), the blob crosses the wire twice).

Exactly-F compiles
------------------
The invariant the scheduler alone cannot give under arbitrary placement —
N clients × F fingerprints → exactly F fleet compiles — comes from the
store serializing compiles per address: the *first* query for an unknown
address gets ``ARTIFACT_MISS`` back and its sender becomes the designated
compiler; every later query for the same address parks in a waiter list
until the compiler's ``ARTIFACT_PUT`` lands, then gets served the blob (or
relayed to the now-resident compiler).  A designated compiler is never
itself blocked on the fleet (it queries exactly when it is about to
build), so the wait chain cannot deadlock; if the compiler dies anyway,
``tick()`` expires the assignment and the waiters get a MISS to compile
for themselves.

Large engines stream as ``ARTIFACT_CHUNK`` runs (``transport.chunk_blob``
/ ``ChunkAssembler``); the binary codec carries the ``blob`` bytes as raw
segments (no JSON/base64 detour — see ``repro.core.codec``).

``DispatchScheduler`` consults ``resident_fp`` (via ``fleet_resident_fn``)
before homing a fresh compile group: a fingerprint the fleet already holds
is a free rider — fetching it is milliseconds, not a compile.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Set

from repro.core.transport import (ARTIFACT_CHUNK, ARTIFACT_FETCH,
                                  ARTIFACT_MISS, ARTIFACT_PUT,
                                  ARTIFACT_QUERY, ChunkAssembler, chunk_blob,
                                  is_artifact_msg)

MODES = ("serve", "relay")


class FleetArtifactStore:
    """Host-side fleet residency map + (serve mode) blob cache.

    Transport-free and clock-injectable like the scheduler: the host feeds
    every pulled artifact frame to ``on_message`` together with a
    ``push(client_id, msg)`` callable, and calls ``tick(push)`` once per
    poll so stale compile/relay assignments expire.
    """

    def __init__(self, mode: str = "serve", *,
                 max_bytes: int = 256 << 20,
                 chunk_bytes: int = 1 << 20,
                 pending_timeout_s: float = 120.0,
                 clock: Callable[[], float] = time.monotonic):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.mode = mode
        self.max_bytes = max_bytes
        self.chunk_bytes = chunk_bytes
        self.pending_timeout_s = pending_timeout_s
        self.clock = clock
        self._blobs: "OrderedDict[str, bytes]" = OrderedDict()
        self._blob_bytes = 0
        self.residency: Dict[str, Set[int]] = {}
        self._fp_addr: Dict[str, str] = {}      # repr(cache_key) -> addr
        # addr -> {"kind": "compile"|"relay", "client": cid,
        #          "deadline": t, "waiters": [cid, ...]}
        self._pending: Dict[str, dict] = {}
        self._rx = ChunkAssembler()
        self.n_hits = 0          # queries served (directly or via relay)
        self.n_misses = 0        # queries that assigned a compiler
        self.n_waits = 0         # queries parked behind an in-flight compile
        self.n_relays = 0        # fetches relayed to a resident peer
        self.n_puts = 0          # PUT frames absorbed (blob or announcement)
        self.n_gone = 0          # relayed fetches that came back empty
        self.n_expired = 0       # pending assignments that timed out
        self.n_evictions = 0     # serve-mode blob-cache LRU evictions
        self.served_bytes = 0    # blob bytes pushed to clients

    # -- residency (the fleet-global CacheShadow generalization) ------------
    def resident_fp(self, fp: str) -> bool:
        """Can the fleet satisfy this fingerprint without a compile?

        True when the blob is host-cached, a client holds it (relayable),
        or its compile is already assigned — in every case a chunk homed on
        a *different* client costs a fetch, not a fresh compile, so
        affinity dispatch treats the group as a free rider.
        """
        addr = self._fp_addr.get(fp)
        if addr is None:
            return False
        return (addr in self._blobs or bool(self.residency.get(addr))
                or addr in self._pending)

    def resident_addrs(self) -> Set[str]:
        out = set(a for a, cids in self.residency.items() if cids)
        out.update(self._blobs)
        return out

    # -- message pump -------------------------------------------------------
    @staticmethod
    def is_artifact_msg(msg) -> bool:
        return is_artifact_msg(msg)

    def on_message(self, msg: dict, push: Callable[[int, dict], None]) -> None:
        cmd = msg.get("cmd")
        if cmd == ARTIFACT_CHUNK:
            done = self._rx.feed(msg)
            if done is None:
                return
            msg, cmd = done, ARTIFACT_PUT
        if cmd == ARTIFACT_QUERY:
            self._on_query(msg, push)
        elif cmd == ARTIFACT_PUT:
            self._on_put(msg, push)
        # FETCH/MISS are host→client only; ignore echoes

    def _on_query(self, msg: dict, push) -> None:
        cid, addr = msg.get("client_id"), msg.get("addr")
        if not isinstance(cid, int) or not isinstance(addr, str):
            return
        self._note_fp(msg)
        spec = bool(msg.get("spec"))
        if addr in self._blobs:                       # host-cached: serve now
            self.n_hits += 1
            self._serve(cid, addr, push)
            return
        pend = self._pending.get(addr)
        if spec:
            # passive prefetch (one wave per incoming batch): serve what
            # exists, join the waiter list of an in-flight compile/relay,
            # but NEVER assign compile duty — a wave landing first would
            # otherwise pile several fingerprints' compiles onto one
            # client.  Always answer (spec MISS) so the collect loop is
            # never parked behind a compile.
            if pend is not None and cid != pend["client"] \
                    and cid not in pend["waiters"]:
                pend["waiters"].append(cid)
                self.n_waits += 1
            elif pend is None and self.mode == "relay":
                peers = [c for c in sorted(self.residency.get(addr, ()))
                         if c != cid]
                if peers:
                    self.n_relays += 1
                    self.n_hits += 1
                    push(peers[0], {"cmd": ARTIFACT_FETCH, "addr": addr,
                                    "fp": msg.get("fp")})
                    self._pending[addr] = {
                        "kind": "relay", "client": peers[0],
                        "deadline": self.clock() + self.pending_timeout_s,
                        "waiters": [cid]}
            push(cid, {"cmd": ARTIFACT_MISS, "addr": addr, "spec": True})
            return
        if pend is not None:                          # compile/relay in flight
            if cid == pend["client"] and pend["kind"] == "compile":
                # the designated compiler asked again (e.g. after a timed-out
                # wait): re-confirm the assignment so it never stalls
                push(cid, {"cmd": ARTIFACT_MISS, "addr": addr})
            elif cid != pend["client"] and cid not in pend["waiters"]:
                pend["waiters"].append(cid)
                self.n_waits += 1
            return
        peers = [c for c in sorted(self.residency.get(addr, ()))
                 if c != cid]
        if self.mode == "relay" and peers:
            self.n_relays += 1
            self.n_hits += 1
            push(peers[0], {"cmd": ARTIFACT_FETCH, "addr": addr,
                            "fp": msg.get("fp")})
            self._pending[addr] = {
                "kind": "relay", "client": peers[0],
                "deadline": self.clock() + self.pending_timeout_s,
                "waiters": [cid]}
            return
        # nothing in the fleet: the asker becomes the designated compiler
        self.n_misses += 1
        self._pending[addr] = {
            "kind": "compile", "client": cid,
            "deadline": self.clock() + self.pending_timeout_s,
            "waiters": []}
        push(cid, {"cmd": ARTIFACT_MISS, "addr": addr})

    def _on_put(self, msg: dict, push) -> None:
        cid, addr = msg.get("client_id"), msg.get("addr")
        if not isinstance(addr, str):
            return
        self._note_fp(msg)
        self.n_puts += 1
        if msg.get("status") == "gone":
            # the relayed peer no longer holds it (LRU'd out and no disk):
            # drop its residency claim and fail the waiters over to compile
            self.n_gone += 1
            if isinstance(cid, int):
                self.residency.get(addr, set()).discard(cid)
            self._fail_pending(addr, push)
            return
        if isinstance(cid, int):
            self.residency.setdefault(addr, set()).add(cid)
        blob = msg.get("blob")
        pend = self._pending.pop(addr, None)
        if isinstance(blob, (bytes, bytearray)):
            blob = bytes(blob)
            if self.mode == "serve":
                self._store_blob(addr, blob)
            waiters = pend["waiters"] if pend else []
            for w in waiters:
                self.n_hits += 1
                self._push_blob(w, addr, blob, push)
            return
        # blob-less residency announcement (relay mode): waiters parked on
        # the compile are relayed to the now-resident compiler
        if pend and pend["waiters"] and isinstance(cid, int):
            self.n_relays += 1
            push(cid, {"cmd": ARTIFACT_FETCH, "addr": addr,
                       "fp": msg.get("fp")})
            self._pending[addr] = {
                "kind": "relay", "client": cid,
                "deadline": self.clock() + self.pending_timeout_s,
                "waiters": list(pend["waiters"])}

    # -- maintenance --------------------------------------------------------
    def tick(self, push: Callable[[int, dict], None]) -> None:
        """Expire stale compile/relay assignments (dead designated clients
        must not park waiters forever)."""
        now = self.clock()
        for addr in [a for a, p in self._pending.items()
                     if now > p["deadline"]]:
            self.n_expired += 1
            self._fail_pending(addr, push)

    def _fail_pending(self, addr: str, push) -> None:
        pend = self._pending.pop(addr, None)
        if pend is None:
            return
        for w in pend["waiters"]:
            try:
                push(w, {"cmd": ARTIFACT_MISS, "addr": addr})
            except Exception:
                pass

    # -- blob cache (serve mode) -------------------------------------------
    def _store_blob(self, addr: str, blob: bytes) -> None:
        old = self._blobs.pop(addr, None)
        if old is not None:
            self._blob_bytes -= len(old)
        self._blobs[addr] = blob
        self._blob_bytes += len(blob)
        while self._blob_bytes > self.max_bytes and len(self._blobs) > 1:
            _, dropped = self._blobs.popitem(last=False)
            self._blob_bytes -= len(dropped)
            self.n_evictions += 1

    def _serve(self, cid: int, addr: str, push) -> None:
        blob = self._blobs[addr]
        self._blobs.move_to_end(addr)                 # LRU touch
        self._push_blob(cid, addr, blob, push)

    def _push_blob(self, cid: int, addr: str, blob: bytes, push) -> None:
        self.served_bytes += len(blob)
        self.residency.setdefault(addr, set()).add(cid)  # it will hold it
        base = {"addr": addr}
        for frame in chunk_blob(base, blob, self.chunk_bytes):
            push(cid, frame)

    # -- introspection ------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        return {
            "fleet_mode": self.mode,
            "fleet_hits": self.n_hits,
            "fleet_misses": self.n_misses,
            "fleet_waits": self.n_waits,
            "fleet_relays": self.n_relays,
            "fleet_puts": self.n_puts,
            "fleet_gone": self.n_gone,
            "fleet_expired": self.n_expired,
            "fleet_blobs": len(self._blobs),
            "fleet_blob_mb": round(self._blob_bytes / 1e6, 6),
            "fleet_evictions": self.n_evictions,
            "fleet_served_mb": round(self.served_bytes / 1e6, 6),
            "fleet_resident_addrs": len(self.resident_addrs()),
            "fleet_pending": len(self._pending),
        }

    def _note_fp(self, msg: dict) -> None:
        fp, addr = msg.get("fp"), msg.get("addr")
        if isinstance(fp, str) and isinstance(addr, str):
            self._fp_addr[fp] = addr
