"""Event-driven dispatch scheduler — the host-side orchestration core.

``JHost.explore`` used to be one monolithic loop owning dispatch, requeue,
deadline, and client-freeing state; this module extracts that state into an
explicitly-testable ``DispatchScheduler`` built from two small state
machines:

* ``Chunk``      — a dispatched group of testConfigs: which client owns it,
  the deadline by which that client must answer it, and the config_ids the
  owner has not answered *itself* yet (a late straggler answering some of a
  chunk's configs records their results but does not free the owner early).
* ``ClientSlot`` — per-client pipeline state: the FIFO of chunk_ids queued
  on that client, an EWMA of observed per-config wall time, and quarantine.

Dispatch policies
-----------------
``eager``     — depth-1: a client receives its next chunk only after fully
  answering its current one (PR 1's batched barrier; ``batch_size=None``
  with this policy is the seed's scalar protocol).
``pipelined`` — depth-2 double-buffering: the scheduler keeps every healthy
  client's config queue two chunks deep, so the next chunk is already
  sitting in the client's transport queue when it finishes the current one —
  the client never idles between its result push and next pull.  Per-chunk
  deadlines stack (a queued chunk's clock starts where its predecessor's
  budget ends) and straggler requeue fails over *all* chunks queued on a
  quarantined client.

Adaptive chunk sizing
---------------------
With ``chunk_budget_s`` set, the scheduler replaces the static
``batch_size`` by targeting a wall-time budget per chunk: each completed
chunk updates the owner's EWMA of per-config wall time (measured from when
the client could *start* the chunk, so queue wait in pipelined mode is not
counted), and the next chunk dispatched to that client is sized
``budget / ewma`` (clamped).  Fast clients get bigger chunks, slow or
jittery clients get smaller ones, and no client holds a chunk much longer
than the budget — which bounds straggler-detection latency too.

The scheduler is transport-free and clock-injectable: the host pushes the
chunks ``next_dispatches()`` returns, feeds every pulled result to
``on_result()``, and calls ``expire()`` each poll; unit tests drive the same
API with a fake clock and no threads.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Set,
                    Tuple)

from repro.core.jconfig import TestConfig

POLICIES = ("eager", "pipelined")


class Chunk:
    """One dispatched chunk: owner, deadline, and unanswered config_ids."""

    __slots__ = ("chunk_id", "client", "deadline", "awaiting", "size",
                 "started_at", "started_seq")

    def __init__(self, chunk_id: int, client: int, deadline: float,
                 awaiting: Set[int], started_at: Optional[float]):
        self.chunk_id = chunk_id
        self.client = client
        self.deadline = deadline
        self.awaiting = awaiting
        self.size = len(awaiting)
        # when the client could begin working on it: dispatch time for the
        # pipeline head, else set when the predecessor chunk completes (None
        # while queued behind another chunk)
        self.started_at = started_at
        # which result batch (pull sequence) marked it started, if any —
        # used to detect client-side chunk coalescing (see _complete_chunk)
        self.started_seq: Optional[int] = None


class ClientSlot:
    """Per-client pipeline: queued chunks, wall-time EWMA, quarantine."""

    __slots__ = ("client_id", "depth_target", "chunks", "ewma_per_cfg_s",
                 "quarantined", "ewma_prev", "obs_start", "obs_configs")

    def __init__(self, client_id: int, depth_target: int):
        self.client_id = client_id
        self.depth_target = depth_target
        self.chunks: List[int] = []         # FIFO of chunk_ids
        self.ewma_per_cfg_s: Optional[float] = None
        self.quarantined = False
        # last EWMA observation, kept revisable: when the client coalesced
        # queued chunks into one evaluate_batch, the successor chunk
        # completes in the same result frame with ~zero measured duration —
        # the predecessor's span covered its work, so the observation is
        # re-done over the combined configs instead of recording a bogus
        # near-zero sample that would deflate the EWMA
        self.ewma_prev: Optional[float] = None
        self.obs_start: Optional[float] = None
        self.obs_configs: int = 0

    def open_chunks(self) -> int:
        return 0 if self.quarantined else max(
            self.depth_target - len(self.chunks), 0)


class DispatchScheduler:
    def __init__(self, client_ids: Sequence[int], *,
                 policy: str = "eager",
                 timeout_s: float = 600.0,
                 max_retries: int = 2,
                 batch_size: Optional[int] = None,
                 chunk_budget_s: Optional[float] = None,
                 min_chunk: int = 1,
                 max_chunk: int = 512,
                 ewma_alpha: float = 0.25,
                 clock: Callable[[], float] = time.monotonic):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        depth = 2 if policy == "pipelined" else 1
        self.policy = policy
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.chunk_budget_s = chunk_budget_s
        self.min_chunk = min_chunk
        self.max_chunk = max_chunk
        self.ewma_alpha = ewma_alpha
        self.clock = clock
        # before any EWMA exists: the static batch_size, or a modest seed
        # chunk when only a budget was given (it adapts from there)
        self.base_chunk = max(int(batch_size or (8 if chunk_budget_s else 1)), 1)
        self.slots: Dict[int, ClientSlot] = {
            c: ClientSlot(c, depth) for c in client_ids}
        self.pending: Deque[Tuple[TestConfig, int]] = deque()
        self.inflight: Dict[int, dict] = {}   # config_id -> {tc, chunk, retries}
        self.chunks: Dict[int, Chunk] = {}
        self.quarantined: Set[int] = set()
        self._chunk_ids = itertools.count()
        self._pull_seq = 0
        self.n_chunks_dispatched = 0
        self.n_configs_dispatched = 0
        # optional wire-stats source (the host attaches its transport's
        # ``wire_summary``); merged into stats() — the scheduler itself
        # stays transport-free
        self.wire_stats_fn: Optional[Callable[[], Dict]] = None

    # -- sizing ---------------------------------------------------------------
    def chunk_size_for(self, slot: ClientSlot) -> int:
        if self.chunk_budget_s is not None and slot.ewma_per_cfg_s:
            want = int(round(self.chunk_budget_s / slot.ewma_per_cfg_s))
            return max(self.min_chunk, min(want, self.max_chunk))
        return self.base_chunk

    # -- intake ---------------------------------------------------------------
    def want(self, lookahead: int = 0) -> int:
        """Fresh configs needed to fill every healthy client's pipeline.

        ``lookahead`` adds that many extra chunks per healthy client to the
        demand — the backpressure signal an async ``SearchDriver`` uses to
        size its precompute buffer, so a freed slot tops up from
        already-computed picks instead of blocking on search math.
        """
        capacity = sum((s.open_chunks() + lookahead) * self.chunk_size_for(s)
                       for s in self.slots.values() if not s.quarantined)
        return max(capacity - len(self.pending), 0)

    def busy(self) -> bool:
        """Anything to wait on?  False means the host cannot make progress
        without fresh submissions — the condition under which it should
        block on the search instead of polling an idle transport."""
        return bool(self.inflight) or bool(self.pending)

    def submit(self, tc: TestConfig) -> None:
        self.pending.append((tc, self.max_retries))

    # -- dispatch -------------------------------------------------------------
    def next_dispatches(self) -> List[Tuple[int, List[TestConfig]]]:
        """Chunks ready to ship: (client_id, configs), pipeline-fair."""
        out: List[Tuple[int, List[TestConfig]]] = []
        progress = True
        while self.pending and progress:
            progress = False
            # one chunk per slot per pass keeps clients evenly loaded
            for slot in self.slots.values():
                if not self.pending:
                    break
                if slot.open_chunks() == 0:
                    continue
                size = min(self.chunk_size_for(slot), len(self.pending))
                items = [self.pending.popleft() for _ in range(size)]
                out.append((slot.client_id, self._dispatch(slot, items)))
                progress = True
        return out

    def _dispatch(self, slot: ClientSlot,
                  items: List[Tuple[TestConfig, int]]) -> List[TestConfig]:
        now = self.clock()
        chunk_id = next(self._chunk_ids)
        if slot.chunks:
            # a queued chunk's budget starts where its predecessor's ends:
            # the client cannot have begun it yet
            base = max(now, self.chunks[slot.chunks[-1]].deadline)
            started = None
        else:
            base = now
            started = now
        chunk = Chunk(chunk_id, slot.client_id,
                      deadline=base + self.timeout_s * len(items),
                      awaiting={tc.config_id for tc, _ in items},
                      started_at=started)
        self.chunks[chunk_id] = chunk
        slot.chunks.append(chunk_id)
        for tc, retries in items:
            self.inflight[tc.config_id] = {"tc": tc, "chunk": chunk_id,
                                           "retries": retries}
        self.n_chunks_dispatched += 1
        self.n_configs_dispatched += len(items)
        return [tc for tc, _ in items]

    # -- results --------------------------------------------------------------
    def note_results(self) -> None:
        """Mark a result-frame boundary (one pulled wire frame).

        The host calls this once before feeding each pull's messages to
        ``on_result``.  Chunks that both *start* and *complete* inside the
        same frame were coalesced by the client into the predecessor's
        evaluate_batch — their wall time belongs to the predecessor's span.
        """
        self._pull_seq += 1

    def on_result(self, msg: dict) -> Optional[TestConfig]:
        """Feed one pulled result message.

        Returns the TestConfig if this is the *first* answer for the config
        (the host records it, rehydrating a slim echo from the returned tc),
        or None for duplicates.  Owner bookkeeping runs either way: the
        reporting client finished this config, and is topped up exactly when
        it has answered its whole chunk itself.
        """
        cid = msg.get("config_id")
        info = self.inflight.pop(cid, None) if cid is not None else None
        tc = info["tc"] if info is not None else None
        reporter = msg.get("client_id")
        if reporter is None and info is not None:
            owner = self.chunks.get(info["chunk"])
            reporter = owner.client if owner is not None else None
        slot = self.slots.get(reporter)
        if slot is not None:
            for chunk_id in list(slot.chunks):
                chunk = self.chunks[chunk_id]
                if cid in chunk.awaiting:
                    chunk.awaiting.discard(cid)
                    if not chunk.awaiting:
                        self._complete_chunk(slot, chunk)
                    break
        return tc

    def _complete_chunk(self, slot: ClientSlot, chunk: Chunk) -> None:
        now = self.clock()
        del self.chunks[chunk.chunk_id]
        slot.chunks.remove(chunk.chunk_id)
        if chunk.started_at is not None:
            if (chunk.started_seq is not None
                    and chunk.started_seq == self._pull_seq
                    and slot.obs_start is not None):
                # coalesced: started *and* completed inside the same result
                # frame — the predecessor's span already covered this work.
                # Revise the previous observation over the combined configs
                # instead of recording a bogus near-zero sample.
                slot.ewma_per_cfg_s = slot.ewma_prev
                slot.obs_configs += chunk.size
            else:
                slot.ewma_prev = slot.ewma_per_cfg_s
                slot.obs_start = chunk.started_at
                slot.obs_configs = chunk.size
            per_cfg = max((now - slot.obs_start) / slot.obs_configs, 1e-9)
            if slot.ewma_per_cfg_s is None:
                slot.ewma_per_cfg_s = per_cfg
            else:
                slot.ewma_per_cfg_s = (self.ewma_alpha * per_cfg
                                       + (1 - self.ewma_alpha)
                                       * slot.ewma_per_cfg_s)
        if slot.chunks:                       # successor starts now
            head = self.chunks[slot.chunks[0]]
            if head.started_at is None:
                head.started_at = now
                head.started_seq = self._pull_seq

    # -- deadlines ------------------------------------------------------------
    def expire(self) -> List[Tuple[TestConfig, int]]:
        """Straggler sweep.  Quarantines clients that blew a chunk deadline
        and fails over every chunk queued on them: survivors with retries
        left rejoin the pending queue; the rest are returned as terminal
        ``(tc, client_id)`` timeouts for the host to record."""
        now = self.clock()
        terminal: List[Tuple[TestConfig, int]] = []
        for chunk_id in list(self.chunks):
            chunk = self.chunks.get(chunk_id)
            if chunk is None or now <= chunk.deadline:
                continue
            slot = self.slots[chunk.client]
            slot.quarantined = True
            self.quarantined.add(chunk.client)
            # the client is gone: chunks queued behind the expired one would
            # never be answered either — fail them all over at once
            for dead_id in list(slot.chunks):
                dead = self.chunks.pop(dead_id)
                for cfg_id in sorted(dead.awaiting):
                    info = self.inflight.get(cfg_id)
                    if info is None or info["chunk"] != dead_id:
                        continue      # already answered (maybe by a peer)
                    del self.inflight[cfg_id]
                    if info["retries"] > 0:
                        self.pending.append((info["tc"], info["retries"] - 1))
                    else:
                        terminal.append((info["tc"], chunk.client))
            slot.chunks.clear()
        return terminal

    # -- introspection --------------------------------------------------------
    def stuck(self) -> bool:
        """No work can ever complete: nothing in flight, everyone dead."""
        return (not self.chunks
                and all(s.quarantined for s in self.slots.values()))

    def stats(self) -> Dict[str, float]:
        busy = sum(1 for s in self.slots.values() if s.chunks)
        s = {
            "pending": len(self.pending),
            "inflight": len(self.inflight),
            "chunks": len(self.chunks),
            "busy_clients": busy,
            "quarantined": len(self.quarantined),
            "chunks_dispatched": self.n_chunks_dispatched,
            "mean_chunk": (self.n_configs_dispatched
                           / max(self.n_chunks_dispatched, 1)),
        }
        if self.wire_stats_fn is not None:
            try:
                s.update(self.wire_stats_fn() or {})
            except Exception:
                pass          # stats must never take the host loop down
        return s
