"""Event-driven dispatch scheduler — the host-side orchestration core.

``JHost.explore`` used to be one monolithic loop owning dispatch, requeue,
deadline, and client-freeing state; this module extracts that state into an
explicitly-testable ``DispatchScheduler`` built from two small state
machines:

* ``Chunk``      — a dispatched group of testConfigs: which client owns it,
  the deadline by which that client must answer it, and the config_ids the
  owner has not answered *itself* yet (a late straggler answering some of a
  chunk's configs records their results but does not free the owner early).
* ``ClientSlot`` — per-client pipeline state: the FIFO of chunk_ids queued
  on that client, an EWMA of observed per-config wall time, quarantine, and
  a ``CacheShadow`` of the sw fingerprints believed resident in that
  client's artifact LRU.

Dispatch policies
-----------------
``eager``     — depth-1: a client receives its next chunk only after fully
  answering its current one (PR 1's batched barrier; ``batch_size=None``
  with this policy is the seed's scalar protocol).
``pipelined`` — depth-N buffering (default 2): the scheduler keeps every
  healthy client's config queue ``pipeline_depth`` chunks deep, so the next
  chunk is already sitting in the client's transport queue when it finishes
  the current one — the client never idles between its result push and next
  pull.  Depth 2 is the classic double-buffer; deeper pipelines hide very
  high-latency links (one chunk in flight per link round-trip).  Per-chunk
  deadlines stack (a queued chunk's clock starts where its predecessor's
  budget ends — at any depth) and straggler requeue fails over *all* chunks
  queued on a quarantined client.

Adaptive chunk sizing
---------------------
With ``chunk_budget_s`` set, the scheduler replaces the static
``batch_size`` by targeting a wall-time budget per chunk: each completed
chunk updates the owner's EWMA of per-config wall time (measured from when
the client could *start* the chunk, so queue wait in pipelined mode is not
counted), and the next chunk dispatched to that client is sized
``budget / ewma`` (clamped).  Fast clients get bigger chunks, slow or
jittery clients get smaller ones, and no client holds a chunk much longer
than the budget — which bounds straggler-detection latency too.

Compile-affinity placement
--------------------------
On a real fleet the dominant cost is artifact *builds* (TensorRT engines /
jit compiles: seconds), not measurements (milliseconds).  With a
``fingerprint_fn`` (normally ``JConfig.cache_key``) the scheduler makes
artifact placement a first-class input: every slot carries a ``CacheShadow``
— an LRU-faithful model of the client's artifact cache, marked optimistically
at dispatch, confirmed from result messages' ``cached`` flags, and resynced
from the ``cache_info`` summary a client attaches to each chunk reply — and
``next_dispatches`` assembles chunks from per-fingerprint buckets of the
pending queue so each dispatch is at most a few compile groups:

* ``affinity="off"``    — PR 2 behaviour: FIFO chunks, fixed slot order.
* ``affinity="prefer"`` — a slot takes groups already resident in its
  shadow first (largest first — tightest compile packing), then unclaimed
  groups (becoming their home), and steals a group resident on another
  healthy client only when it would otherwise sit completely idle.
* ``affinity="strict"`` — never steals: a group resident on a healthy
  client waits for that client (its shadow is cleared on quarantine, so a
  dead home never strands work).

Speculative re-dispatch
-----------------------
With ``speculate_frac`` set, a running head chunk that has consumed that
fraction of its deadline budget without completing is mirrored to a second
client — chosen by shadow affinity, falling back to least-loaded.  First
answer wins: results are deduped by the existing first-answer-only inflight
table, the losing twin chunk is cancelled host-side (removed from its
slot's queue; its late answers ride the existing duplicate path), and a
quarantined primary hands its configs to the live mirror instead of
re-queueing them.  The losing client may still be computing the cancelled
chunk, so its next EWMA observation can read slightly slow — the price of
never waiting out a full deadline on a straggler.

With ``speculate_slow_mult`` set (independently of ``speculate_frac``),
chunks still *queued* — not yet started — behind a client whose per-config
EWMA exceeds that multiple of the median of the other healthy clients'
EWMAs are mirrored too ("queued" kind): the slow client has not begun them,
so a copy elsewhere is pure insurance.  ``stats()`` reports the queued-kind
dispatch and win counters separately (``spec_queued*``).

The scheduler is transport-free and clock-injectable: the host pushes the
chunks ``next_dispatches()`` returns, feeds every pulled result to
``on_result()``, and calls ``expire()`` each poll; unit tests drive the same
API with a fake clock and no threads.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, Hashable, List, Optional,
                    Sequence, Set, Tuple)

from repro.core.jconfig import TestConfig

POLICIES = ("eager", "pipelined")
AFFINITIES = ("off", "prefer", "strict")


class CacheShadow:
    """Host-side model of one client's artifact LRU.

    Mirrors ``JClient._artifact`` exactly: a hit refreshes the key's
    recency; a miss inserts it, evicting the least-recently-used entry
    first when the cache is already at capacity.  Each entry records
    whether it is *confirmed* (learned from a result message: the client
    really holds it) or an *optimistic* dispatch mark (the client will hold
    it once it evaluates the chunk — unless the chunk fails).  ``resync``
    folds in the authoritative ``cache_info`` counters a client attaches
    to its chunk replies: when the model holds more entries than the
    client reports, the newest unconfirmed marks are dropped first, and
    only then confirmed entries from the LRU end.
    """

    __slots__ = ("capacity", "_d", "evictions")

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._d: Dict[Hashable, bool] = {}   # fp -> confirmed; ins. order
        self.evictions = 0                   # == LRU order

    def __contains__(self, fp: Hashable) -> bool:
        return fp in self._d

    def __len__(self) -> int:
        return len(self._d)

    def keys(self) -> List[Hashable]:
        """Resident fingerprints, least-recently-used first."""
        return list(self._d)

    def touch(self, fp: Hashable, confirmed: bool = True) -> bool:
        """Mark ``fp`` used; returns True when it was already resident."""
        if fp in self._d:
            # refresh recency (true LRU); confirmation is sticky
            self._d[fp] = self._d.pop(fp) or confirmed
            return True
        if len(self._d) >= self.capacity:            # evict before insert,
            self._d.pop(next(iter(self._d)))         # like JClient._artifact
            self.evictions += 1
        self._d[fp] = confirmed
        return False

    def resync(self, currsize: Optional[int], maxsize: Optional[int]) -> None:
        if maxsize is not None and maxsize > 0:
            self.capacity = int(maxsize)
        if currsize is None:
            return
        excess = len(self._d) - max(int(currsize), 0)
        if excess <= 0:
            return
        # the model drifted ahead of the client: unconfirmed optimistic
        # marks (e.g. for a chunk that failed) are the suspects — drop the
        # newest of those first, never a confirmed-resident entry before
        # every optimistic one is gone
        for fp in [f for f, ok in reversed(self._d.items()) if not ok]:
            if excess <= 0:
                break
            del self._d[fp]
            excess -= 1
        while excess > 0:
            self._d.pop(next(iter(self._d)))         # confirmed: LRU-first
            excess -= 1

    def clear(self) -> None:
        self._d.clear()


class Chunk:
    """One dispatched chunk: owner, deadline, and unanswered config_ids."""

    __slots__ = ("chunk_id", "client", "deadline", "awaiting", "size",
                 "started_at", "started_seq", "fps", "mirror_id", "mirror_of",
                 "spec_kind")

    def __init__(self, chunk_id: int, client: int, deadline: float,
                 awaiting: Set[int], started_at: Optional[float]):
        self.chunk_id = chunk_id
        self.client = client
        self.deadline = deadline
        self.awaiting = awaiting
        self.size = len(awaiting)
        # when the client could begin working on it: dispatch time for the
        # pipeline head, else set when the predecessor chunk completes (None
        # while queued behind another chunk)
        self.started_at = started_at
        # which result batch (pull sequence) marked it started, if any —
        # used to detect client-side chunk coalescing (see _complete_chunk)
        self.started_seq: Optional[int] = None
        # ordered unique sw fingerprints of the chunk's configs (known only
        # when the scheduler has a fingerprint_fn)
        self.fps: List[Hashable] = []
        # speculative-twin links: a primary points at its mirror and vice
        # versa; both awaiting sets shrink in lockstep (first answer wins)
        self.mirror_id: Optional[int] = None    # set on the primary
        self.mirror_of: Optional[int] = None    # set on the mirror
        # why a mirror exists: "deadline" (speculate_frac on a running head)
        # or "queued" (speculate_slow_mult on a not-yet-started chunk queued
        # behind a very slow client) — routes win/cancel counters
        self.spec_kind: Optional[str] = None    # set on the mirror


class ClientSlot:
    """Per-client pipeline: queued chunks, wall-time EWMA, quarantine, and
    the shadow of the client's artifact cache."""

    __slots__ = ("client_id", "depth_target", "chunks", "ewma_per_cfg_s",
                 "quarantined", "ewma_prev", "obs_start", "obs_configs",
                 "shadow")

    def __init__(self, client_id: int, depth_target: int,
                 cache_size: int = 64):
        self.client_id = client_id
        self.depth_target = depth_target
        self.chunks: List[int] = []         # FIFO of chunk_ids
        self.ewma_per_cfg_s: Optional[float] = None
        self.quarantined = False
        self.shadow = CacheShadow(cache_size)
        # last EWMA observation, kept revisable: when the client coalesced
        # queued chunks into one evaluate_batch, the successor chunk
        # completes in the same result frame with ~zero measured duration —
        # the predecessor's span covered its work, so the observation is
        # re-done over the combined configs instead of recording a bogus
        # near-zero sample that would deflate the EWMA
        self.ewma_prev: Optional[float] = None
        self.obs_start: Optional[float] = None
        self.obs_configs: int = 0

    def open_chunks(self) -> int:
        return 0 if self.quarantined else max(
            self.depth_target - len(self.chunks), 0)


class DispatchScheduler:
    def __init__(self, client_ids: Sequence[int], *,
                 policy: str = "eager",
                 timeout_s: float = 600.0,
                 max_retries: int = 2,
                 batch_size: Optional[int] = None,
                 chunk_budget_s: Optional[float] = None,
                 min_chunk: int = 1,
                 max_chunk: int = 512,
                 ewma_alpha: float = 0.25,
                 affinity: str = "off",
                 fingerprint_fn: Optional[Callable[[TestConfig],
                                                   Hashable]] = None,
                 client_cache_size: int = 64,
                 speculate_frac: Optional[float] = None,
                 speculate_slow_mult: Optional[float] = None,
                 pipeline_depth: Optional[int] = None,
                 fleet_resident_fn: Optional[Callable[[Hashable],
                                                      bool]] = None,
                 clock: Callable[[], float] = time.monotonic):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, got {policy!r}")
        if affinity not in AFFINITIES:
            raise ValueError(
                f"affinity must be one of {AFFINITIES}, got {affinity!r}")
        if affinity != "off" and fingerprint_fn is None:
            raise ValueError("affinity placement needs a fingerprint_fn "
                             "(e.g. JConfig.cache_key)")
        if speculate_frac is not None and not 0.0 < speculate_frac <= 1.0:
            raise ValueError(f"speculate_frac must be in (0, 1], "
                             f"got {speculate_frac!r}")
        if speculate_slow_mult is not None and speculate_slow_mult <= 1.0:
            raise ValueError(f"speculate_slow_mult must be > 1.0, "
                             f"got {speculate_slow_mult!r}")
        if pipeline_depth is not None:
            depth = int(pipeline_depth)
            if depth < 1:
                raise ValueError(f"pipeline_depth must be >= 1, got {depth}")
        else:
            depth = 2 if policy == "pipelined" else 1
        self.policy = policy
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.chunk_budget_s = chunk_budget_s
        self.min_chunk = min_chunk
        self.max_chunk = max_chunk
        self.ewma_alpha = ewma_alpha
        self.affinity = affinity
        self.fingerprint_fn = fingerprint_fn
        self.speculate_frac = speculate_frac
        self.speculate_slow_mult = speculate_slow_mult
        # fleet artifact store consult: fingerprint -> "resident somewhere
        # in the fleet" (host blob cache / peer disk / compile in flight).
        # A fleet-resident group costs a fetch, not a compile, wherever it
        # lands — so it neither binds placement nor consumes the
        # one-fresh-compile-group-per-chunk budget
        self.fleet_resident_fn = fleet_resident_fn
        self.clock = clock
        # before any EWMA exists: the static batch_size, or a modest seed
        # chunk when only a budget was given (it adapts from there)
        self.base_chunk = max(int(batch_size or (8 if chunk_budget_s else 1)), 1)
        self.slots: Dict[int, ClientSlot] = {
            c: ClientSlot(c, depth, client_cache_size) for c in client_ids}
        self.pending: Deque[Tuple[TestConfig, int]] = deque()
        self.inflight: Dict[int, dict] = {}   # config_id -> {tc, chunk, retries}
        self.chunks: Dict[int, Chunk] = {}
        self.quarantined: Set[int] = set()
        self._chunk_ids = itertools.count()
        self._pull_seq = 0
        self._fp: Dict[int, Hashable] = {}    # config_id -> sw fingerprint
        self.n_chunks_dispatched = 0
        self.n_configs_dispatched = 0
        self.n_fp_chunks = 0        # chunks whose fingerprints were known
        self.n_affine_chunks = 0    # ... placed on a client already holding
        #                             their leading fingerprint
        self.n_fleet_rides = 0      # fresh groups taken free: fleet-resident
        self.n_speculated = 0       # mirror chunks dispatched (all kinds)
        self.n_spec_wins_primary = 0
        self.n_spec_wins_mirror = 0
        self.n_spec_cancelled = 0   # losing twins cancelled host-side
        self.n_spec_queued = 0      # queued-chunk mirrors (slow-client kind)
        self.n_spec_queued_wins_primary = 0
        self.n_spec_queued_wins_mirror = 0
        # optional wire-stats source (the host attaches its transport's
        # ``wire_summary``); merged into stats() — the scheduler itself
        # stays transport-free
        self.wire_stats_fn: Optional[Callable[[], Dict]] = None

    # -- sizing ---------------------------------------------------------------
    def chunk_size_for(self, slot: ClientSlot) -> int:
        if self.chunk_budget_s is not None and slot.ewma_per_cfg_s:
            want = int(round(self.chunk_budget_s / slot.ewma_per_cfg_s))
            return max(self.min_chunk, min(want, self.max_chunk))
        return self.base_chunk

    # -- intake ---------------------------------------------------------------
    def want(self, lookahead: int = 0) -> int:
        """Fresh configs needed to fill every healthy client's pipeline.

        ``lookahead`` adds that many extra chunks per healthy client to the
        demand — the backpressure signal an async ``SearchDriver`` uses to
        size its precompute buffer, so a freed slot tops up from
        already-computed picks instead of blocking on search math.
        """
        capacity = sum((s.open_chunks() + lookahead) * self.chunk_size_for(s)
                       for s in self.slots.values() if not s.quarantined)
        return max(capacity - len(self.pending), 0)

    def busy(self) -> bool:
        """Anything to wait on?  False means the host cannot make progress
        without fresh submissions — the condition under which it should
        block on the search instead of polling an idle transport."""
        return bool(self.inflight) or bool(self.pending)

    def submit(self, tc: TestConfig) -> None:
        if self.fingerprint_fn is not None:
            self._fp[tc.config_id] = self.fingerprint_fn(tc)
        self.pending.append((tc, self.max_retries))

    # -- dispatch -------------------------------------------------------------
    def next_dispatches(self) -> List[Tuple[int, List[TestConfig]]]:
        """Chunks ready to ship: (client_id, configs), pipeline-fair.

        With affinity on, slots fill least-loaded-first from per-fingerprint
        buckets of the pending queue (see ``_take_affine``); speculative
        mirrors of nearly-expired chunks are emitted first, so a straggler's
        insurance rides the same push the fresh work does.
        """
        out: List[Tuple[int, List[TestConfig]]] = []
        if self.speculate_frac is not None or \
                self.speculate_slow_mult is not None:
            out.extend(self._speculative_dispatches())
        if not self.pending or not any(
                s.open_chunks() for s in self.slots.values()):
            return out                # steady state: skip the bucketing work
        if self.affinity == "off":
            progress = True
            while self.pending and progress:
                progress = False
                # one chunk per slot per pass keeps clients evenly loaded
                for slot in self.slots.values():
                    if not self.pending:
                        break
                    if slot.open_chunks() == 0:
                        continue
                    size = min(self.chunk_size_for(slot), len(self.pending))
                    items = [self.pending.popleft() for _ in range(size)]
                    out.append((slot.client_id, self._dispatch(slot, items)))
                    progress = True
            return out
        # affinity: bucket the pending queue by fingerprint ONCE per call
        # (arrival order preserved per bucket and, via seq, overall), then
        # let every slot-pass consume from the shared buckets
        groups: Dict[Hashable, Deque[Tuple[int, Tuple[TestConfig, int]]]] = {}
        for seq, item in enumerate(self.pending):
            fp = self._fp.get(item[0].config_id)
            if fp not in groups:
                groups[fp] = deque()
            groups[fp].append((seq, item))
        n_left = len(self.pending)
        progress = True
        while n_left and progress:
            progress = False
            # least-loaded first so the non-affine fallback balances
            for slot in sorted(self.slots.values(),
                               key=lambda s: (len(s.chunks), s.client_id)):
                if n_left == 0:
                    break
                if slot.open_chunks() == 0:
                    continue
                size = min(self.chunk_size_for(slot), n_left)
                items = self._take_affine(slot, size, groups)
                if not items:
                    continue      # strict: this slot's work lives elsewhere
                n_left -= len(items)
                out.append((slot.client_id, self._dispatch(slot, items)))
                progress = True
        if n_left != len(self.pending):
            left = sorted((e for q in groups.values() for e in q),
                          key=lambda e: e[0])
            self.pending = deque(item for _, item in left)
        return out

    def _take_affine(self, slot: ClientSlot, size: int,
                     groups: Dict[Hashable, Deque]) -> List[Tuple[TestConfig,
                                                                  int]]:
        """Up to ``size`` items for ``slot``, consumed from the shared
        per-fingerprint buckets.

        Groups are ranked: resident in this slot's shadow first (largest
        first — tightest compile packing), then groups the *fleet store*
        already holds (a fetch, not a compile, wherever they land), then
        groups resident on no healthy client (this slot becomes their
        home), then — only in ``prefer`` mode and only when the slot is
        completely idle — groups resident on another healthy client.
        Whole groups are taken head-first until the chunk is full, so a
        dispatch is at most a few compile groups — and at most ONE of them
        not yet compiled anywhere: padding a chunk with the head of a
        second fresh group would claim it for this client, skewing group
        ownership across the fleet and serializing its compiles here;
        resident groups — shadow- or fleet-resident — are free riders.
        """
        here: List[Hashable] = []
        fleet: List[Hashable] = []
        unclaimed: List[Hashable] = []
        elsewhere: List[Hashable] = []
        for fp, q in groups.items():
            if not q:
                continue
            if fp is not None and fp in slot.shadow:
                here.append(fp)
            elif fp is not None and self._fleet_resident(fp):
                fleet.append(fp)         # fetchable anywhere: free rider
            elif fp is not None and any(
                    fp in s.shadow for s in self.slots.values()
                    if s is not slot and not s.quarantined):
                elsewhere.append(fp)
            else:
                unclaimed.append(fp)     # no affinity signal: first taker
        here.sort(key=lambda f: -len(groups[f]))
        fleet.sort(key=lambda f: -len(groups[f]))
        ranked = here + fleet + unclaimed
        if self.affinity == "prefer" and not slot.chunks:
            ranked += elsewhere          # steal rather than idle
        fleet_set = set(fleet)
        taken: List[Tuple[TestConfig, int]] = []
        new_group_taken = False
        for fp in ranked:
            if len(taken) >= size:
                break
            free = (fp is not None and fp in slot.shadow) or fp in fleet_set
            if not free:
                if new_group_taken:      # one fresh compile group per chunk
                    continue
                new_group_taken = True
            q = groups[fp]
            took_any = False
            while q and len(taken) < size:
                taken.append(q.popleft()[1])
                took_any = True
            if took_any and fp in fleet_set:
                self.n_fleet_rides += 1
        return taken

    def _fleet_resident(self, fp: Hashable) -> bool:
        if self.fleet_resident_fn is None:
            return False
        try:
            return bool(self.fleet_resident_fn(fp))
        except Exception:
            return False  # a stats probe must never take dispatch down

    def _dispatch(self, slot: ClientSlot,
                  items: List[Tuple[TestConfig, int]]) -> List[TestConfig]:
        now = self.clock()
        chunk_id = next(self._chunk_ids)
        if slot.chunks:
            # a queued chunk's budget starts where its predecessor's ends:
            # the client cannot have begun it yet
            base = max(now, self.chunks[slot.chunks[-1]].deadline)
            started = None
        else:
            base = now
            started = now
        chunk = Chunk(chunk_id, slot.client_id,
                      deadline=base + self.timeout_s * len(items),
                      awaiting={tc.config_id for tc, _ in items},
                      started_at=started)
        if self.fingerprint_fn is not None:
            seen: Set[Hashable] = set()
            for tc, _ in items:
                fp = self._fp.get(tc.config_id)
                if fp is not None and fp not in seen:
                    seen.add(fp)
                    chunk.fps.append(fp)
            if chunk.fps:
                self.n_fp_chunks += 1
                if chunk.fps[0] in slot.shadow:
                    self.n_affine_chunks += 1
                # optimistic: the client will hold these once it evaluates
                # the chunk (confirmed/corrected by result `cached` flags
                # and the reply's cache_info resync)
                for fp in chunk.fps:
                    slot.shadow.touch(fp, confirmed=False)
        self.chunks[chunk_id] = chunk
        slot.chunks.append(chunk_id)
        for tc, retries in items:
            self.inflight[tc.config_id] = {"tc": tc, "chunk": chunk_id,
                                           "retries": retries}
        self.n_chunks_dispatched += 1
        self.n_configs_dispatched += len(items)
        return [tc for tc, _ in items]

    # -- speculation ----------------------------------------------------------
    def _speculative_dispatches(self) -> List[Tuple[int, List[TestConfig]]]:
        """Mirror chunks at risk onto a second client (shadow-affine, else
        least loaded).  Two triggers, independently enabled: a *running*
        head chunk that burned ``speculate_frac`` of its deadline budget
        ("deadline" kind), and chunks still *queued* (not yet started)
        behind a client whose per-config EWMA exceeds
        ``speculate_slow_mult`` × the median of the other healthy clients'
        EWMAs ("queued" kind — the work hasn't begun, so moving a copy is
        pure insurance, not a race against sunk cost).  First answer wins;
        see ``_cancel_twin``."""
        now = self.clock()
        out: List[Tuple[int, List[TestConfig]]] = []
        if self.speculate_frac is not None:
            for slot in self.slots.values():
                if slot.quarantined or not slot.chunks:
                    continue
                head = self.chunks[slot.chunks[0]]
                if (head.mirror_id is not None or head.mirror_of is not None
                        or head.started_at is None or not head.awaiting):
                    continue
                budget = head.deadline - head.started_at
                if budget <= 0 or (now - head.started_at) < \
                        self.speculate_frac * budget:
                    continue
                target = self._mirror_target(slot, head)
                if target is None:
                    continue
                disp = self._mirror_chunk(head, target, now, "deadline")
                if disp is not None:
                    out.append(disp)
        if self.speculate_slow_mult is not None:
            out.extend(self._queued_speculative(now))
        return out

    def _queued_speculative(self, now: float
                            ) -> List[Tuple[int, List[TestConfig]]]:
        """Mirror queued (not yet started) chunks of very slow clients."""
        mult = self.speculate_slow_mult
        out: List[Tuple[int, List[TestConfig]]] = []
        healthy = [s for s in self.slots.values()
                   if not s.quarantined and s.ewma_per_cfg_s is not None]
        for slot in healthy:
            if len(slot.chunks) < 2:
                continue
            # median of the OTHER healthy clients' EWMAs: with the slow slot
            # excluded, a 2-client fleet still yields a sane reference (a
            # plain all-slots median would sit between the two speeds)
            others = sorted(s.ewma_per_cfg_s for s in healthy if s is not slot)
            if not others:
                continue
            ref = others[len(others) // 2] if len(others) % 2 else \
                0.5 * (others[len(others) // 2 - 1]
                       + others[len(others) // 2])
            if ref <= 0 or slot.ewma_per_cfg_s <= mult * ref:
                continue
            for chunk_id in list(slot.chunks[1:]):
                chunk = self.chunks[chunk_id]
                if (chunk.started_at is not None
                        or chunk.mirror_id is not None
                        or chunk.mirror_of is not None
                        or not chunk.awaiting):
                    continue
                target = self._mirror_target(slot, chunk)
                if target is None:
                    return out             # fleet has no spare depth left
                disp = self._mirror_chunk(chunk, target, now, "queued")
                if disp is not None:
                    self.n_spec_queued += 1
                    out.append(disp)
        return out

    def _mirror_chunk(self, src: Chunk, target: ClientSlot, now: float,
                      kind: str) -> Optional[Tuple[int, List[TestConfig]]]:
        """Create and enqueue the speculative twin of ``src`` on ``target``.

        Mirrors only what is still unanswered AND in flight: a cid the owner
        still awaits but a late straggler already answered is not re-sent,
        so it must not be awaited from the mirror either (it could never
        answer it — the chunk would hang forever)."""
        tcs = [self.inflight[c]["tc"] for c in sorted(src.awaiting)
               if c in self.inflight]
        if not tcs:
            return None
        mirror_id = next(self._chunk_ids)
        if target.chunks:
            base = max(now, self.chunks[target.chunks[-1]].deadline)
            started = None
        else:
            base = now
            started = now
        mirror = Chunk(mirror_id, target.client_id,
                       deadline=base + self.timeout_s * len(tcs),
                       awaiting={tc.config_id for tc in tcs},
                       started_at=started)
        mirror.mirror_of = src.chunk_id
        mirror.spec_kind = kind
        mirror.fps = list(src.fps)
        src.mirror_id = mirror_id
        self.chunks[mirror_id] = mirror
        target.chunks.append(mirror_id)
        for fp in mirror.fps:
            target.shadow.touch(fp, confirmed=False)
        self.n_speculated += 1
        return (target.client_id, tcs)

    def _mirror_target(self, owner: ClientSlot,
                       chunk: Chunk) -> Optional[ClientSlot]:
        best: Optional[Tuple[Tuple[int, int, int], ClientSlot]] = None
        for slot in self.slots.values():
            if slot is owner or slot.quarantined or slot.open_chunks() == 0:
                continue
            overlap = sum(1 for fp in chunk.fps if fp in slot.shadow)
            key = (-overlap, len(slot.chunks), slot.client_id)
            if best is None or key < best[0]:
                best = (key, slot)
        return best[1] if best is not None else None

    def _twin(self, chunk: Chunk) -> Optional[Chunk]:
        tid = chunk.mirror_id if chunk.mirror_id is not None \
            else chunk.mirror_of
        return self.chunks.get(tid) if tid is not None else None

    def _cancel_twin(self, winner: Chunk, loser: Chunk) -> None:
        """Host-side cancel of the losing twin: its slot is freed now; any
        answers the losing client still pushes ride the duplicate path."""
        self.chunks.pop(loser.chunk_id, None)
        lslot = self.slots.get(loser.client)
        if lslot is not None and loser.chunk_id in lslot.chunks:
            was_head = lslot.chunks[0] == loser.chunk_id
            lslot.chunks.remove(loser.chunk_id)
            if was_head and lslot.chunks:
                succ = self.chunks[lslot.chunks[0]]
                if succ.started_at is None:
                    succ.started_at = self.clock()
                    succ.started_seq = self._pull_seq
        winner.mirror_id = winner.mirror_of = None
        self.n_spec_cancelled += 1
        mirror = loser if loser.mirror_of is not None else winner
        queued = mirror.spec_kind == "queued"
        if loser.mirror_of is not None:       # the mirror lost: primary won
            if queued:
                self.n_spec_queued_wins_primary += 1
            else:
                self.n_spec_wins_primary += 1
        else:
            if queued:
                self.n_spec_queued_wins_mirror += 1
            else:
                self.n_spec_wins_mirror += 1

    # -- results --------------------------------------------------------------
    def note_results(self) -> None:
        """Mark a result-frame boundary (one pulled wire frame).

        The host calls this once before feeding each pull's messages to
        ``on_result``.  Chunks that both *start* and *complete* inside the
        same frame were coalesced by the client into the predecessor's
        evaluate_batch — their wall time belongs to the predecessor's span.
        """
        self._pull_seq += 1

    def on_result(self, msg: dict) -> Optional[TestConfig]:
        """Feed one pulled result message.

        Returns the TestConfig if this is the *first* answer for the config
        (the host records it, rehydrating a slim echo from the returned tc),
        or None for duplicates.  Owner bookkeeping runs either way: the
        reporting client finished this config, and is topped up exactly when
        it has answered its whole chunk itself.  Shadow learning rides the
        same message: the reporter's ``CacheShadow`` is touched with the
        config's fingerprint (confirming the optimistic dispatch mark) and
        resynced from any attached ``cache_info`` summary.
        """
        cid = msg.get("config_id")
        info = self.inflight.pop(cid, None) if cid is not None else None
        tc = info["tc"] if info is not None else None
        reporter = msg.get("client_id")
        if reporter is None and info is not None:
            owner = self.chunks.get(info["chunk"])
            reporter = owner.client if owner is not None else None
        slot = self.slots.get(reporter)
        if slot is not None:
            if self.fingerprint_fn is not None:
                fp = self._fp.get(cid)
                if fp is not None and (msg.get("cached")
                                       or msg.get("status") == "ok"):
                    slot.shadow.touch(fp)
                ci = msg.get("cache_info")
                if isinstance(ci, dict):
                    slot.shadow.resync(ci.get("currsize"), ci.get("maxsize"))
            for chunk_id in list(slot.chunks):
                chunk = self.chunks[chunk_id]
                if cid in chunk.awaiting:
                    chunk.awaiting.discard(cid)
                    twin = self._twin(chunk)
                    if twin is not None:
                        # twins shrink in lockstep: the other copy of this
                        # config's work is no longer awaited either
                        twin.awaiting.discard(cid)
                    if not chunk.awaiting:
                        if twin is not None:
                            self._cancel_twin(chunk, twin)
                        self._complete_chunk(slot, chunk)
                    elif twin is not None and not twin.awaiting:
                        # the twin emptied via cross-discards (it awaited a
                        # subset — e.g. a mirror of a chunk with an already
                        # straggler-answered cid): nothing left for it to
                        # answer, so free its slot now
                        self._cancel_twin(chunk, twin)
                    break
        if tc is not None:
            self._fp.pop(cid, None)
        return tc

    def _complete_chunk(self, slot: ClientSlot, chunk: Chunk) -> None:
        now = self.clock()
        del self.chunks[chunk.chunk_id]
        slot.chunks.remove(chunk.chunk_id)
        if chunk.started_at is not None:
            if (chunk.started_seq is not None
                    and chunk.started_seq == self._pull_seq
                    and slot.obs_start is not None):
                # coalesced: started *and* completed inside the same result
                # frame — the predecessor's span already covered this work.
                # Revise the previous observation over the combined configs
                # instead of recording a bogus near-zero sample.
                slot.ewma_per_cfg_s = slot.ewma_prev
                slot.obs_configs += chunk.size
            else:
                slot.ewma_prev = slot.ewma_per_cfg_s
                slot.obs_start = chunk.started_at
                slot.obs_configs = chunk.size
            per_cfg = max((now - slot.obs_start) / slot.obs_configs, 1e-9)
            if slot.ewma_per_cfg_s is None:
                slot.ewma_per_cfg_s = per_cfg
            else:
                slot.ewma_per_cfg_s = (self.ewma_alpha * per_cfg
                                       + (1 - self.ewma_alpha)
                                       * slot.ewma_per_cfg_s)
        if slot.chunks:                       # successor starts now
            head = self.chunks[slot.chunks[0]]
            if head.started_at is None:
                head.started_at = now
                head.started_seq = self._pull_seq

    # -- deadlines ------------------------------------------------------------
    def expire(self) -> List[Tuple[TestConfig, int]]:
        """Straggler sweep.  Quarantines clients that blew a chunk deadline
        and fails over every chunk queued on them: configs covered by a live
        speculative twin are handed to the twin, survivors with retries
        left rejoin the pending queue, and the rest are returned as terminal
        ``(tc, client_id)`` timeouts for the host to record."""
        now = self.clock()
        terminal: List[Tuple[TestConfig, int]] = []
        for chunk_id in list(self.chunks):
            chunk = self.chunks.get(chunk_id)
            if chunk is None or now <= chunk.deadline:
                continue
            slot = self.slots[chunk.client]
            slot.quarantined = True
            self.quarantined.add(chunk.client)
            # the client is gone: chunks queued behind the expired one would
            # never be answered either — fail them all over at once
            for dead_id in list(slot.chunks):
                dead = self.chunks.pop(dead_id)
                twin = self._twin(dead)
                for cfg_id in sorted(dead.awaiting):
                    info = self.inflight.get(cfg_id)
                    if info is None or info["chunk"] != dead_id:
                        continue      # already answered (maybe by a peer)
                    if twin is not None and cfg_id in twin.awaiting:
                        # the live mirror already carries this config:
                        # re-point ownership instead of re-queueing
                        info["chunk"] = twin.chunk_id
                        continue
                    del self.inflight[cfg_id]
                    if info["retries"] > 0:
                        self.pending.append((info["tc"], info["retries"] - 1))
                    else:
                        self._fp.pop(cfg_id, None)
                        terminal.append((info["tc"], chunk.client))
                if twin is not None:          # survivor completes standalone
                    twin.mirror_id = twin.mirror_of = None
            slot.chunks.clear()
            # a quarantined client's artifacts are unreachable: without this,
            # strict affinity would strand its fingerprints forever
            slot.shadow.clear()
        return terminal

    # -- introspection --------------------------------------------------------
    def resident_fingerprints(self) -> Set[Hashable]:
        """Union of sw fingerprints resident in healthy clients' shadows —
        the fleet-level compile-residency snapshot a shadow-aware searcher
        biases its candidate pools toward (``SearchAlgorithm.note_residency``)."""
        out: Set[Hashable] = set()
        for slot in self.slots.values():
            if not slot.quarantined:
                out.update(slot.shadow.keys())
        return out

    def stuck(self) -> bool:
        """No work can ever complete: nothing in flight, everyone dead."""
        return (not self.chunks
                and all(s.quarantined for s in self.slots.values()))

    def stats(self) -> Dict[str, Any]:
        busy = sum(1 for s in self.slots.values() if s.chunks)
        s: Dict[str, Any] = {
            "pending": len(self.pending),
            "inflight": len(self.inflight),
            "chunks": len(self.chunks),
            "busy_clients": busy,
            "quarantined": len(self.quarantined),
            "chunks_dispatched": self.n_chunks_dispatched,
            "mean_chunk": (self.n_configs_dispatched
                           / max(self.n_chunks_dispatched, 1)),
        }
        if self.fingerprint_fn is not None:
            s["affinity"] = self.affinity
            s["fp_chunks"] = self.n_fp_chunks
            s["affine_chunks"] = self.n_affine_chunks
            s["shadow_sizes"] = {c: len(sl.shadow)
                                 for c, sl in self.slots.items()}
        if self.fleet_resident_fn is not None:
            s["fleet_rides"] = self.n_fleet_rides
        if self.speculate_frac is not None or \
                self.speculate_slow_mult is not None:
            s["speculated"] = self.n_speculated
            s["spec_wins_primary"] = self.n_spec_wins_primary
            s["spec_wins_mirror"] = self.n_spec_wins_mirror
            s["spec_cancelled"] = self.n_spec_cancelled
        if self.speculate_slow_mult is not None:
            s["spec_queued"] = self.n_spec_queued
            s["spec_queued_wins_primary"] = self.n_spec_queued_wins_primary
            s["spec_queued_wins_mirror"] = self.n_spec_queued_wins_mirror
        if self.wire_stats_fn is not None:
            try:
                s.update(self.wire_stats_fn() or {})
            except Exception:
                pass          # stats must never take the host loop down
        return s
