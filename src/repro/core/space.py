"""Design-space definition — the Table-I analogue for TPU pods.

A ``DesignSpace`` is an ordered list of discrete ``Knob``s.  Knobs are either
``hw`` (hardware-ladder values that only re-evaluate the analytic measurement
model — the Jetson frequency knobs) or ``sw`` (values that change the lowered
HLO and force a re-compile — there is no Jetson analogue because Jetson doesn't
recompile, but on a compiler-scheduled architecture these ARE the design
space).  JClient caches compiled artifacts keyed by the sw subset.

Knob applicability can be conditioned on the architecture/shape (e.g. the
attention-tiling knobs are masked out for the attention-free mamba2 arch, per
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.roofline import hw as hwmod

KIND_HW = "hw"
KIND_SW = "sw"


@dataclasses.dataclass(frozen=True)
class Knob:
    name: str
    values: Tuple[Any, ...]
    kind: str = KIND_HW

    def __post_init__(self):
        assert self.kind in (KIND_HW, KIND_SW)
        assert len(self.values) >= 1


class DesignSpace:
    def __init__(self, knobs: Sequence[Knob]):
        self.knobs: List[Knob] = list(knobs)
        self._by_name = {k.name: k for k in self.knobs}
        assert len(self._by_name) == len(self.knobs), "duplicate knob names"

    # -- basic ----------------------------------------------------------------
    def __iter__(self):
        return iter(self.knobs)

    def __getitem__(self, name: str) -> Knob:
        return self._by_name[name]

    def names(self) -> List[str]:
        return [k.name for k in self.knobs]

    def size(self) -> int:
        n = 1
        for k in self.knobs:
            n *= len(k.values)
        return n

    def default(self) -> Dict[str, Any]:
        return {k.name: k.values[-1] for k in self.knobs}

    # -- sampling / encoding ----------------------------------------------------
    def sample(self, rng: np.random.Generator) -> Dict[str, Any]:
        return {k.name: k.values[rng.integers(len(k.values))] for k in self.knobs}

    def sample_index_batch(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """``(n, K)`` int64 matrix of value indices — a whole candidate pool
        in K vectorized rng calls instead of n·K scalar ones."""
        if not self.knobs:
            return np.zeros((n, 0), np.int64)
        return np.stack([rng.integers(len(k.values), size=n)
                         for k in self.knobs], axis=1)

    def sample_batch(self, rng: np.random.Generator, n: int) -> List[Dict]:
        return self.index_decode_batch(self.sample_index_batch(rng, n))

    def index_decode_batch(self, idx: np.ndarray) -> List[Dict]:
        names = [k.name for k in self.knobs]
        values = [k.values for k in self.knobs]
        return [{nm: vs[int(i)] for nm, vs, i in zip(names, values, row)}
                for row in np.asarray(idx)]

    def index_encode_batch(self, configs: Sequence[Dict[str, Any]]) -> np.ndarray:
        """``(n, K)`` int64 value-index matrix for a list of configs."""
        luts = [{v: i for i, v in enumerate(k.values)} for k in self.knobs]
        return np.asarray([[lut[c[k.name]]
                            for lut, k in zip(luts, self.knobs)]
                           for c in configs], np.int64).reshape(len(configs),
                                                                len(self.knobs))

    def encode_index_batch(self, idx: np.ndarray) -> np.ndarray:
        """Normalise an ``(n, K)`` index matrix to [0, 1] coordinates (the
        batch analogue of ``encode``, one broadcast divide)."""
        scale = np.asarray([max(len(k.values) - 1, 1) for k in self.knobs],
                           np.float64)
        return np.asarray(idx, np.float64) / scale

    def encode_batch(self, configs: Sequence[Dict[str, Any]]) -> np.ndarray:
        """``(n, K)`` search coordinates for a list of configs in one shot."""
        return self.encode_index_batch(self.index_encode_batch(configs))

    def encode(self, config: Dict[str, Any]) -> np.ndarray:
        """Ordinal indices normalised to [0, 1] — search-algorithm coordinates."""
        out = []
        for k in self.knobs:
            i = k.values.index(config[k.name])
            out.append(i / max(len(k.values) - 1, 1))
        return np.asarray(out, dtype=np.float64)

    def decode(self, vec: np.ndarray) -> Dict[str, Any]:
        cfg = {}
        for k, x in zip(self.knobs, vec):
            i = int(round(float(np.clip(x, 0.0, 1.0)) * (len(k.values) - 1)))
            cfg[k.name] = k.values[i]
        return cfg

    def index_encode(self, config: Dict[str, Any]) -> np.ndarray:
        return np.asarray([k.values.index(config[k.name]) for k in self.knobs], np.int64)

    def index_decode(self, idx: np.ndarray) -> Dict[str, Any]:
        return {k.name: k.values[int(i) % len(k.values)] for k, i in zip(self.knobs, idx)}

    def mutate(self, config: Dict[str, Any], rng: np.random.Generator,
               p: float = 0.25) -> Dict[str, Any]:
        """±1-step ordinal mutation (frequency ladders are ordered)."""
        out = dict(config)
        for k in self.knobs:
            if len(k.values) > 1 and rng.random() < p:
                i = k.values.index(out[k.name])
                step = int(rng.choice([-1, 1]))
                out[k.name] = k.values[int(np.clip(i + step, 0, len(k.values) - 1))]
        return out


# ---------------------------------------------------------------------------
# The production TPU-pod space (Table-I analogue)
# ---------------------------------------------------------------------------


def tpu_pod_space(arch=None, shape=None, n_chips: int = 256,
                  include_sw: bool = True) -> DesignSpace:
    """Build the default space, masking knobs inapplicable to (arch, shape)."""
    knobs: List[Knob] = [
        Knob("clock_scale", hwmod.CLOCK_LADDER, KIND_HW),
        Knob("hbm_scale", hwmod.HBM_LADDER, KIND_HW),
        Knob("ici_scale", hwmod.ICI_LADDER, KIND_HW),
    ]
    if not include_sw:
        return DesignSpace(knobs)

    is_train = shape is None or shape.kind == "train"
    has_attn = arch is None or arch.n_heads > 0
    has_ssm = arch is None or arch.ssm_state > 0
    batch = None if shape is None else shape.global_batch

    # mesh factorisation: dp · tp = n_chips (the "# cores per cluster" analogue)
    dps = [d for d in (4, 8, 16, 32, 64) if n_chips % d == 0
           and (batch is None or batch % d == 0)]
    if not dps:
        dps = [1]
    knobs.append(Knob("dp_degree", tuple(dps), KIND_SW))
    knobs.append(Knob("dtype", ("bfloat16",), KIND_SW))
    knobs.append(Knob("fsdp", (False, True), KIND_SW))
    if is_train:
        knobs += [
            Knob("microbatch", (1, 2, 4), KIND_SW),
            Knob("remat", ("none", "selective", "full"), KIND_SW),
            Knob("sp", (False, True), KIND_SW),
            Knob("grad_rs", (False, True), KIND_SW),
            Knob("loss_chunks", (1, 8), KIND_SW),
        ]
    if has_attn:
        knobs += [
            Knob("attn_block_q", (128, 256, 512), KIND_SW),
            Knob("attn_block_kv", (128, 256, 512), KIND_SW),
        ]
    if has_ssm:
        knobs.append(Knob("ssd_chunk", (128, 256, 512), KIND_SW))
    return DesignSpace(knobs)
