"""JHost — the host-side orchestrator (paper §III, Algorithm 1).

Interfaces a user-defined search algorithm with N clients:
  * batch dispatch — as many in-flight configs as there are free clients, so
    batch-sampling search algorithms "work faster" (paper contribution 2);
  * straggler mitigation / fault tolerance — every dispatched config carries a
    deadline; on timeout it is re-queued to a healthy client (up to
    ``max_retries``), and the late client is quarantined;
  * result saving — every result lands in a ResultStore (CSV streaming).
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.jconfig import TestConfig
from repro.core.results import ResultRecord, ResultStore
from repro.core.search.base import SearchAlgorithm
from repro.core.transport import HostTransport


class JHost:
    def __init__(self, transport: HostTransport,
                 store: Optional[ResultStore] = None,
                 timeout_s: float = 600.0,
                 max_retries: int = 2,
                 poll_s: float = 0.05):
        self.transport = transport
        self.store = store if store is not None else ResultStore()
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.poll_s = poll_s
        self.quarantined: set = set()

    # -- Algorithm 1, JHOST procedure -----------------------------------------
    def explore(self, search: SearchAlgorithm, arch: str, shape: str,
                n_samples: int,
                objectives: Sequence[str] = ("time_s", "power_w"),
                progress: bool = False) -> ResultStore:
        ids = itertools.count()
        free: List[int] = [c for c in self.transport.client_ids()]
        inflight: Dict[int, dict] = {}   # config_id -> {tc, client, deadline, retries}
        issued = completed = 0

        def dispatch(tc: TestConfig, retries: int):
            client = free.pop(0)
            self.transport.push(client, tc.to_wire())
            inflight[tc.config_id] = {
                "tc": tc, "client": client,
                "deadline": time.monotonic() + self.timeout_s,
                "retries": retries,
            }

        while completed < n_samples:
            # fill free clients with fresh asks
            n_new = min(len(free), n_samples - issued)
            if n_new > 0:
                for knobs in search.ask(n_new):
                    tc = TestConfig(next(ids), arch, shape, knobs)
                    dispatch(tc, self.max_retries)
                    issued += 1

            msg = self.transport.pull(self.poll_s)
            now = time.monotonic()

            if msg is not None:
                cid = msg["config_id"]
                info = inflight.pop(cid, None)
                if info is None:
                    continue  # late duplicate from a quarantined straggler
                client = msg.get("client_id", info["client"])
                if client not in self.quarantined:
                    free.append(client)
                rec = ResultRecord.from_wire(msg)
                self.store.add(rec)
                completed += 1
                if rec.status == "ok":
                    y = np.asarray([rec.metrics[k] for k in objectives], float)
                    search.tell(rec.knobs, y)
                if progress and completed % 10 == 0:
                    print(f"[jhost] {completed}/{n_samples} "
                          f"(inflight={len(inflight)}, free={len(free)})")

            # straggler sweep
            for cid, info in list(inflight.items()):
                if now <= info["deadline"]:
                    continue
                del inflight[cid]
                self.quarantined.add(info["client"])
                if info["retries"] > 0 and free:
                    dispatch(info["tc"], info["retries"] - 1)
                else:
                    self.store.add(ResultRecord(
                        config_id=cid, arch=arch, shape=shape,
                        knobs=info["tc"].knobs, metrics={}, status="timeout",
                        client_id=info["client"]))
                    completed += 1

            if not inflight and not free and completed < n_samples:
                raise RuntimeError("all clients quarantined; exploration stuck")
        return self.store

    def stop_clients(self) -> None:
        for c in self.transport.client_ids():
            try:
                self.transport.push(c, {"cmd": "stop"})
            except Exception:
                pass
