"""JHost — the host-side orchestrator (paper §III, Algorithm 1).

Interfaces a user-defined search algorithm with N clients:
  * batch dispatch — as many in-flight configs as there are free clients, so
    batch-sampling search algorithms "work faster" (paper contribution 2);
    with ``batch_size=B`` the host asks the search for client-count×B chunks
    and ships each chunk as one framed transport message, and the client
    answers with one batched result frame (the group-by-compile fast path);
  * straggler mitigation / fault tolerance — every dispatched chunk carries a
    deadline; on timeout the late client is quarantined and the chunk's
    surviving configs are re-queued (split across whichever clients free up
    next, up to ``max_retries`` per config).  Configs with retries remaining
    are never dropped just because no client is free at sweep time — they
    wait in a pending queue;
  * result saving — every result lands in a ResultStore (CSV streaming).

Scalar mode (``batch_size=None``) is the degenerate chunk-of-1 case and keeps
the original one-testConfig-per-message wire format.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jconfig import TestConfig
from repro.core.results import ResultRecord, ResultStore
from repro.core.search.base import SearchAlgorithm
from repro.core.transport import HostTransport


class JHost:
    def __init__(self, transport: HostTransport,
                 store: Optional[ResultStore] = None,
                 timeout_s: float = 600.0,
                 max_retries: int = 2,
                 poll_s: float = 0.05):
        self.transport = transport
        self.store = store if store is not None else ResultStore()
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.poll_s = poll_s
        self.quarantined: set = set()

    # -- Algorithm 1, JHOST procedure -----------------------------------------
    def explore(self, search: SearchAlgorithm, arch: str, shape: str,
                n_samples: int,
                objectives: Sequence[str] = ("time_s", "power_w"),
                progress: bool = False,
                batch_size: Optional[int] = None) -> ResultStore:
        chunk = max(int(batch_size or 1), 1)
        ids = itertools.count()
        bids = itertools.count()
        free: List[int] = [c for c in self.transport.client_ids()]
        # configs awaiting (re)dispatch: fresh asks and timed-out survivors
        pending: Deque[Tuple[TestConfig, int]] = deque()
        inflight: Dict[int, dict] = {}      # config_id -> {tc, batch, retries}
        batches: Dict[int, dict] = {}       # batch_id -> {client, deadline, awaiting}
        client_batch: Dict[int, int] = {}   # client -> its current batch_id
        issued = completed = 0

        def dispatch(items: List[Tuple[TestConfig, int]]) -> None:
            client = free.pop(0)
            self.transport.push_many(client, [tc.to_wire() for tc, _ in items])
            bid = next(bids)
            batches[bid] = {
                "client": client,
                # the deadline covers the whole chunk: a B-config batch gets
                # B× the single-config budget
                "deadline": time.monotonic() + self.timeout_s * len(items),
                # configs this client has not answered *itself* yet — the
                # client is freed only once this empties, even when a late
                # straggler answers some of its configs first
                "awaiting": {tc.config_id for tc, _ in items},
            }
            client_batch[client] = bid
            for tc, retries in items:
                inflight[tc.config_id] = {"tc": tc, "batch": bid,
                                          "retries": retries}

        while completed < n_samples:
            # top up the pending queue with fresh asks, then fill free clients
            want = min(n_samples - issued,
                       max(len(free) * chunk - len(pending), 0))
            if want > 0:
                for knobs in search.ask(want):
                    pending.append((TestConfig(next(ids), arch, shape, knobs),
                                    self.max_retries))
                    issued += 1
            while free and pending:
                dispatch([pending.popleft()
                          for _ in range(min(chunk, len(pending)))])

            msgs = self.transport.pull_many(self.poll_s)
            now = time.monotonic()

            for msg in msgs:
                cid = msg["config_id"]
                info = inflight.pop(cid, None)
                if info is not None:        # first answer for this config
                    if "knobs" not in msg:  # slim batch result: rehydrate echo
                        tc = info["tc"]
                        msg["knobs"], msg["arch"], msg["shape"] = \
                            tc.knobs, tc.arch, tc.shape
                    rec = ResultRecord.from_wire(msg)
                    self.store.add(rec)
                    completed += 1
                    if rec.status == "ok":
                        y = np.asarray([rec.metrics[k] for k in objectives],
                                       float)
                        search.tell(rec.knobs, y)
                    if progress and completed % 10 == 0:
                        print(f"[jhost] {completed}/{n_samples} "
                              f"(inflight={len(inflight)}, free={len(free)}, "
                              f"pending={len(pending)})")
                # owner bookkeeping runs even for duplicate answers: the
                # *reporting* client finished this config either way, and is
                # freed exactly when it has answered its whole chunk itself
                reporter = msg.get("client_id")
                if reporter is None and info is not None:
                    reporter = batches.get(info["batch"], {}).get("client")
                bid = client_batch.get(reporter)
                if bid is not None:
                    batch = batches[bid]
                    batch["awaiting"].discard(cid)
                    if not batch["awaiting"]:
                        del batches[bid]
                        del client_batch[reporter]
                        if reporter not in self.quarantined:
                            free.append(reporter)

            # straggler sweep: expire whole batches, requeue their survivors
            for bid, batch in list(batches.items()):
                if now <= batch["deadline"]:
                    continue
                del batches[bid]
                client_batch.pop(batch["client"], None)
                self.quarantined.add(batch["client"])
                for cid in sorted(batch["awaiting"]):
                    info = inflight.get(cid)
                    if info is None or info["batch"] != bid:
                        continue  # already answered (possibly by a late peer)
                    del inflight[cid]
                    if info["retries"] > 0:
                        # survivors wait for the next free client instead of
                        # being dropped as terminal timeouts
                        pending.append((info["tc"], info["retries"] - 1))
                    else:
                        self.store.add(ResultRecord(
                            config_id=cid, arch=arch, shape=shape,
                            knobs=info["tc"].knobs, metrics={},
                            status="timeout", client_id=batch["client"]))
                        completed += 1

            if (not inflight and not free and not client_batch
                    and completed < n_samples):
                raise RuntimeError("all clients quarantined; exploration stuck")
        return self.store

    def stop_clients(self) -> None:
        for c in self.transport.client_ids():
            try:
                self.transport.push(c, {"cmd": "stop"})
            except Exception:
                pass
