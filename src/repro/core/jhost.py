"""JHost — the host-side orchestrator (paper §III, Algorithm 1).

Interfaces a user-defined search algorithm with N clients.  Since the
scheduler refactor, JHost is a thin facade: all dispatch, requeue, deadline,
and client-freeing state lives in ``repro.core.scheduler.DispatchScheduler``
(explicit ``Chunk``/``ClientSlot`` state machines, testable without threads
or transports); JHost's loop just moves data between the search algorithm,
the transport, the scheduler, and the ResultStore:

  * batch dispatch — the scheduler asks for ``batch_size``-config chunks per
    free client (``dispatch="eager"``, PR 1's barrier), or keeps every
    client's queue two chunks deep (``dispatch="pipelined"`` double-
    buffering, so clients never idle between result push and next pull);
  * adaptive chunk sizing — with ``chunk_budget_ms`` the static batch_size
    is replaced by a per-client EWMA-targeted wall-time budget per chunk;
  * straggler mitigation / fault tolerance — every chunk carries a deadline;
    on timeout the late client is quarantined and surviving configs are
    re-queued (up to ``max_retries`` per config), waiting in the pending
    queue if no client is free at sweep time; with ``speculate_frac`` a
    nearly-expired chunk is mirrored to a second client first (first answer
    wins) so a straggler costs one speculation, not a full deadline;
  * compile-affinity placement — with ``affinity`` + ``fingerprint_fn``
    (normally ``JConfig.cache_key``) the scheduler tracks which sw
    fingerprints each client holds compiled and routes same-fingerprint
    chunks back to that client (see ``repro.core.scheduler``);
  * fleet artifact store — with a ``fleet_store``
    (``repro.core.fleet.FleetArtifactStore``) the loop intercepts
    ``artifact_*`` frames from the result stream and feeds them to the
    store, which serves/relays compiled artifacts between clients and
    enforces exactly-one-compile-per-fingerprint fleet-wide; the
    scheduler additionally treats fleet-resident fingerprints as free
    riders when homing compile groups;
  * result saving — every result lands in a ResultStore (CSV streaming);
  * async search overlap — when ``search`` is a ``SearchDriver`` (it
    exposes ``poll_ask``/``note_demand``), the loop feeds the scheduler's
    backpressure (``want(lookahead=1)``) to the driver and tops the
    pipeline up from precomputed asks without blocking on GP math; it only
    blocks on the search when nothing is in flight (``sched.busy()``).

Scalar mode (``batch_size=None``, eager) is the degenerate chunk-of-1 case
and keeps the original one-testConfig-per-message wire format.
"""
from __future__ import annotations

import itertools
from typing import Optional, Sequence

import numpy as np

from repro.core.fleet import FleetArtifactStore
from repro.core.jconfig import TestConfig
from repro.core.results import ResultRecord, ResultStore
from repro.core.scheduler import DispatchScheduler
from repro.core.search.base import SearchAlgorithm
from repro.core.transport import HostTransport, is_artifact_msg


class JHost:
    def __init__(self, transport: HostTransport,
                 store: Optional[ResultStore] = None,
                 timeout_s: float = 600.0,
                 max_retries: int = 2,
                 poll_s: float = 0.05):
        self.transport = transport
        self.store = store if store is not None else ResultStore()
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.poll_s = poll_s
        self.quarantined: set = set()
        self.scheduler: Optional[DispatchScheduler] = None

    # -- Algorithm 1, JHOST procedure -----------------------------------------
    def explore(self, search: SearchAlgorithm, arch: str, shape: str,
                n_samples: int,
                objectives: Sequence[str] = ("time_s", "power_w"),
                progress: bool = False,
                batch_size: Optional[int] = None,
                dispatch: str = "eager",
                chunk_budget_ms: Optional[float] = None,
                affinity: str = "off",
                fingerprint_fn=None,
                client_cache_size: int = 64,
                speculate_frac: Optional[float] = None,
                speculate_slow_mult: Optional[float] = None,
                pipeline_depth: Optional[int] = None,
                fleet_store: Optional[FleetArtifactStore] = None,
                scheduler: Optional[DispatchScheduler] = None) -> ResultStore:
        # fleet residency consult for affinity dispatch: a fingerprint the
        # fleet store can serve is a fetch, not a compile, wherever it lands
        fleet_resident_fn = None
        if fleet_store is not None and fingerprint_fn is not None:
            fleet_resident_fn = \
                lambda fp, _fs=fleet_store: _fs.resident_fp(repr(fp))
        sched = scheduler if scheduler is not None else DispatchScheduler(
            self.transport.client_ids(), policy=dispatch,
            timeout_s=self.timeout_s, max_retries=self.max_retries,
            batch_size=batch_size,
            chunk_budget_s=(None if chunk_budget_ms is None
                            else chunk_budget_ms / 1e3),
            affinity=affinity, fingerprint_fn=fingerprint_fn,
            client_cache_size=client_cache_size,
            speculate_frac=speculate_frac,
            speculate_slow_mult=speculate_slow_mult,
            pipeline_depth=pipeline_depth,
            fleet_resident_fn=fleet_resident_fn)
        self.scheduler = sched
        self.quarantined = sched.quarantined   # shared set, stays live
        sched.wire_stats_fn = getattr(self.transport, "wire_summary", None)
        ids = itertools.count()
        issued = completed = 0
        # an async SearchDriver exposes poll_ask/note_demand: the host tops
        # the pipeline up from its precomputed buffer without blocking on
        # search math while results are in flight, and only blocks when the
        # loop cannot otherwise progress
        poll_ask = getattr(search, "poll_ask", None)
        note_demand = getattr(search, "note_demand", None)
        # shadow-aware pools: with a fingerprint_fn the searcher learns which
        # sw fingerprints are resident in the fleet's cache shadows and
        # biases its candidate pools toward them (no-ops for searchers
        # without the hooks)
        note_residency = None
        if fingerprint_fn is not None:
            set_fp_fn = getattr(search, "set_sw_fingerprint_fn", None)
            if set_fp_fn is not None:
                set_fp_fn(lambda knobs, _a=arch, _s=shape:
                          fingerprint_fn(TestConfig(-1, _a, _s, knobs)))
            note_residency = getattr(search, "note_residency", None)

        while completed < n_samples:
            # top up the pending queue with fresh asks, then fill pipelines
            want = min(n_samples - issued, sched.want())
            if want > 0:
                if note_residency is not None:
                    note_residency(sched.resident_fingerprints())
                if poll_ask is not None:
                    if note_demand is not None:
                        note_demand(min(n_samples - issued,
                                        sched.want(lookahead=1)))
                    cfgs = poll_ask(want, need=not sched.busy())
                else:
                    cfgs = search.ask(want)
                for knobs in cfgs:
                    sched.submit(TestConfig(next(ids), arch, shape, knobs))
                    issued += 1
            for client, tcs in sched.next_dispatches():
                self.transport.push_many(client, [tc.to_wire() for tc in tcs])

            msgs = self.transport.pull_many(self.poll_s)
            if fleet_store is not None:
                # artifact traffic rides the same sockets as results but is
                # the store's business, not the scheduler's
                arts = [m for m in msgs if is_artifact_msg(m)]
                if arts:
                    msgs = [m for m in msgs if not is_artifact_msg(m)]
                    for m in arts:
                        fleet_store.on_message(m, self.transport.push)
                fleet_store.tick(self.transport.push)
            if msgs:
                sched.note_results()   # frame boundary: coalescing detection
            for msg in msgs:
                tc = sched.on_result(msg)
                if tc is None:          # duplicate answer: bookkeeping only
                    continue
                if "knobs" not in msg:  # slim batch result: rehydrate echo
                    msg["knobs"], msg["arch"], msg["shape"] = \
                        tc.knobs, tc.arch, tc.shape
                rec = ResultRecord.from_wire(msg)
                self.store.add(rec)
                completed += 1
                if rec.status == "ok":
                    y = np.asarray([rec.metrics[k] for k in objectives],
                                   float)
                    search.tell(rec.knobs, y)
                if progress and completed % 10 == 0:
                    s = sched.stats()
                    wire = ""
                    if "wire_out_mb" in s:
                        wire = (f", wire {s['wire_out_mb']:.2f}/"
                                f"{s['wire_in_mb']:.2f} MB "
                                f"{s.get('codec', '?')}")
                    print(f"[jhost] {completed}/{n_samples} "
                          f"(inflight={s['inflight']:.0f}, "
                          f"pending={s['pending']:.0f}, "
                          f"chunk~{s['mean_chunk']:.1f}{wire})")

            # straggler sweep: requeue survivors, record terminal timeouts
            for tc, client in sched.expire():
                self.store.add(ResultRecord(
                    config_id=tc.config_id, arch=arch, shape=shape,
                    knobs=tc.knobs, metrics={}, status="timeout",
                    client_id=client))
                completed += 1

            if completed < n_samples and sched.stuck():
                raise RuntimeError("all clients quarantined; exploration stuck")
        return self.store

    def stop_clients(self) -> None:
        for c in self.transport.client_ids():
            try:
                self.transport.push(c, {"cmd": "stop"})
            except Exception:
                pass
