"""JClient — the device-side worker (paper §III, Algorithm 1).

Capabilities, mirroring the paper:
  1. configure the device + workload from a received testConfig (JConfig);
  2. measure (JMeasure set, enable/disable at construction);
  3. communicate with the host (any ClientTransport).

The workload is injected as ``build_fn(TestConfig) -> (Artifact, meta)`` —
"the workloads can be anything as JExplore is agnostic to the workload".
Compiled artifacts are cached by the sw-knob fingerprint, the analogue of the
network staying resident on a Jetson while only clocks change.  The cache is
a true LRU: a hit refreshes the key, so hot sw-points survive long sweeps
that touch more unique fingerprints than ``cache_size``.

Persistent artifact cache (``cache_dir``)
-----------------------------------------
With ``cache_dir`` set, the in-memory LRU becomes the *hot tier* of a
two-tier cache: every freshly built ``BuildResult`` is also pickled to disk,
content-addressed, and an in-memory miss tries the disk tier before calling
``build_fn`` — the analogue of an on-disk TensorRT engine cache, so a
restarted client (or a repeated sweep) skips the compile entirely for every
fingerprint it has ever built.

Layout: ``<cache_dir>/<hh>/<hash>.pkl`` where ``hash`` is the SHA-256 of
``repr((JConfig.identity(), cache_key))`` and ``hh`` its first two hex
chars (keeps directories small on big sweeps).  Each file holds
``{"v": _DISK_CACHE_VERSION, "key": repr(cache_key), "built": BuildResult}``
written atomically (tmp file + ``os.replace``), so concurrent clients may
share a directory — last writer wins, and readers never see a torn file.

Invalidation rules: the address covers everything that determines the
artifact — the jconfig identity (design-space knob names/values/kinds +
``n_chips``) and the full ``cache_key`` (arch, shape, sw-knob values) — so
changing any of those naturally misses.  What the address *cannot* see is
the body of ``build_fn`` itself: if the workload builder changes
behaviourally, bump ``_DISK_CACHE_VERSION`` or delete the directory.  A
corrupt/unreadable/version-mismatched file is treated as a miss and
overwritten; entries are never aged out automatically.

``cache_info()`` reports both tiers, and ``serve`` attaches the summary to
every chunk reply (one ``cache_info`` sidecar per result frame) — the
host's ``DispatchScheduler`` uses it to keep its per-client cache shadow
honest for compile-affinity placement.

Batched fast path (group-by-compile)
------------------------------------
``evaluate_batch`` is the throughput-oriented entry point.  It groups the
incoming configs by their sw-knob fingerprint (``JConfig.cache_key``),
compiles each unique sw-group **once**, then sweeps every hw-knob variant of
the group through the vectorized measurement path
(``JMeasure.measure_batch`` over an ``HwModelBatch`` of ``(N,)`` ladder
arrays).  Compile work is therefore O(unique sw-points) instead of
O(configs), and per-config Python/dict overhead collapses into a handful of
numpy sweeps — metrics stay bit-identical to the scalar ``evaluate`` path.
``serve`` speaks both wire formats: a plain testConfig message is evaluated
scalar; a ``{"cmd": "batch", "items": [...]}`` frame (see transport.py) runs
``evaluate_batch`` and pushes one batched result frame back.  Under a
double-buffering host (``dispatch="pipelined"``) several chunks may already
be sitting in the transport queue when the client wakes up — ``serve``
drains every queued batch frame first and coalesces them into a **single**
``evaluate_batch`` call, so speculative chunks share one group-by-compile
sweep and come back as one result frame.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import time
import traceback
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jconfig import JConfig, TestConfig
from repro.core.jmeasure import DEFAULT_MEASURES, JMeasure
from repro.core.transport import (BATCH_CMD, BATCH_COLS_CMD, ClientTransport,
                                  unframe_batch)
from repro.roofline.analysis import Artifact

BuildResult = Tuple[Artifact, Dict]

# bump when BuildResult semantics change behaviourally for the same address
# (the content hash cannot see the body of build_fn)
_DISK_CACHE_VERSION = 1


class JClient:
    def __init__(self, jconfig: JConfig,
                 build_fn: Callable[[TestConfig], BuildResult],
                 measures: Sequence[JMeasure] = DEFAULT_MEASURES,
                 transport: Optional[ClientTransport] = None,
                 client_id: int = 0,
                 cache_size: int = 64,
                 cache_dir: Optional[str] = None):
        self.jconfig = jconfig
        self.build_fn = build_fn
        self.measures = tuple(measures)
        self.transport = transport
        self.client_id = client_id
        self._cache: Dict[tuple, BuildResult] = {}
        self._cache_size = cache_size
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_evictions = 0
        self.cache_dir = cache_dir
        self._disk_hits = 0
        self._disk_misses = 0
        self._disk_stores = 0
        self.n_evaluated = 0
        self.n_compiled = 0
        if cache_dir is not None:
            os.makedirs(cache_dir, exist_ok=True)

    # -- persistent tier (content-addressed pickles, see module docstring) ----
    def _disk_path(self, key: tuple) -> str:
        h = hashlib.sha256(
            repr((self.jconfig.identity(), key)).encode("utf-8")).hexdigest()
        return os.path.join(self.cache_dir, h[:2], h + ".pkl")

    def _disk_load(self, key: tuple) -> Optional[BuildResult]:
        try:
            with open(self._disk_path(key), "rb") as f:
                payload = pickle.load(f)
            if (payload.get("v") == _DISK_CACHE_VERSION
                    and payload.get("key") == repr(key)):
                return payload["built"]
        except Exception:
            pass          # missing / torn / stale-format file == miss
        return None

    def _disk_store(self, key: tuple, built: BuildResult) -> None:
        """Best-effort atomic write; an unpicklable artifact (live device
        buffers, etc.) simply stays memory-only.  The tmp file name comes
        from mkstemp, so concurrent writers — including client threads
        sharing one process — can never interleave into one file."""
        path = self._disk_path(key)
        tmp = None
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                pickle.dump({"v": _DISK_CACHE_VERSION, "key": repr(key),
                             "built": built}, f)
            os.replace(tmp, path)
            self._disk_stores += 1
        except Exception:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    # -- artifact cache (LRU hot tier keyed by sw fingerprint) ----------------
    def _artifact(self, key: tuple, tc: TestConfig) -> BuildResult:
        if key in self._cache:
            self._cache[key] = self._cache.pop(key)  # refresh: true LRU
            self._cache_hits += 1
            return self._cache[key]
        self._cache_misses += 1
        built = None
        if self.cache_dir is not None:
            built = self._disk_load(key)
            if built is not None:
                self._disk_hits += 1
            else:
                self._disk_misses += 1
        if built is None:
            built = self.build_fn(tc)
            self.n_compiled += 1
            if self.cache_dir is not None:
                self._disk_store(key, built)
        if len(self._cache) >= self._cache_size:
            self._cache.pop(next(iter(self._cache)))  # least-recently used
            self._cache_evictions += 1
        self._cache[key] = built
        return built

    def cache_info(self) -> Dict[str, int]:
        """functools-style counters for the artifact cache, both tiers."""
        info = {"hits": self._cache_hits, "misses": self._cache_misses,
                "evictions": self._cache_evictions,
                "currsize": len(self._cache), "maxsize": self._cache_size}
        if self.cache_dir is not None:
            info.update({"disk_hits": self._disk_hits,
                         "disk_misses": self._disk_misses,
                         "disk_stores": self._disk_stores})
        return info

    # -- single evaluation -------------------------------------------------
    def evaluate(self, tc: TestConfig) -> dict:
        t0 = time.monotonic()
        key = self.jconfig.cache_key(tc)
        cached = key in self._cache
        try:
            art, meta = self._artifact(key, tc)
            hw = self.jconfig.hw_model(tc.knobs)
            metrics: Dict[str, float] = {}
            for m in self.measures:
                metrics.update(m.measure(art, hw, meta))
            status = "ok"
        except Exception:
            metrics = {}
            status = "failed"
            metrics["error"] = traceback.format_exc(limit=3)
        self.n_evaluated += 1
        return {
            "config_id": tc.config_id,
            "arch": tc.arch,
            "shape": tc.shape,
            "knobs": tc.knobs,
            "metrics": metrics,
            "status": status,
            "client_id": self.client_id,
            "cached": cached,
            "wall_s": time.monotonic() - t0,
        }

    # -- batched evaluation (group-by-compile) --------------------------------
    def evaluate_batch(self, tcs: Sequence[TestConfig]) -> List[dict]:
        """Evaluate a batch with one compile per unique sw fingerprint.

        Result dicts are ordered like ``tcs`` and carry exactly the scalar
        ``evaluate`` schema; metric values are bit-identical to N scalar
        calls (the vectorized sweep mirrors the scalar arithmetic op-for-op).
        """
        results: List[Optional[dict]] = [None] * len(tcs)
        groups: Dict[tuple, List[int]] = {}
        for i, tc in enumerate(tcs):
            groups.setdefault(self.jconfig.cache_key(tc), []).append(i)

        for key, idxs in groups.items():
            g0 = time.monotonic()
            was_cached = key in self._cache
            cols: Dict[str, np.ndarray] = {}
            try:
                art, meta = self._artifact(key, tcs[idxs[0]])
                hwb = self.jconfig.hw_model_batch([tcs[i].knobs for i in idxs])
                for m in self.measures:
                    cols.update(m.measure_batch(art, hwb, meta))
            except Exception:
                # scalar-parity fallback: a group-level failure (bad build, or
                # one hw variant tripping a measure) must not fail sibling
                # configs that would survive the scalar path — re-evaluate the
                # group one config at a time
                for i in idxs:
                    results[i] = self.evaluate(tcs[i])
                    self.n_evaluated -= 1   # evaluate() counted it; the batch
                    # total is added once at the end for all of tcs
                continue
            # one C-level tolist per metric column beats N×K .item() calls
            names = list(cols)
            rows = [np.asarray(cols[k]).tolist() for k in names]
            wall = (time.monotonic() - g0) / len(idxs)  # amortized per config
            for j, i in enumerate(idxs):
                tc = tcs[i]
                results[i] = {
                    "config_id": tc.config_id,
                    "arch": tc.arch,
                    "shape": tc.shape,
                    "knobs": tc.knobs,
                    "metrics": {k: col[j] for k, col in zip(names, rows)},
                    "status": "ok",
                    "client_id": self.client_id,
                    # sequential-scalar parity: the group's first config pays
                    # the compile, the rest ride the cache
                    "cached": was_cached or j > 0,
                    "wall_s": wall,
                }
        self.n_evaluated += len(tcs)
        return results  # type: ignore[return-value]

    # -- Algorithm 1, JCLIENT procedure ---------------------------------------
    def _drain_pending(self, first: dict):
        """Coalesce every already-queued batch frame behind ``first``.

        A pipelined host keeps ≥2 chunks in this client's queue; evaluating
        them as one batch shares the group-by-compile sweep.  Returns
        (batch_frames, scalar_msgs, stop_seen) in arrival order.
        """
        frames, scalars, stop = [first], [], False
        while True:
            nxt = self.transport.pull(0.0)
            if nxt is None:
                break
            cmd = nxt.get("cmd")
            if cmd == "stop":
                stop = True
                break
            if cmd in (BATCH_CMD, BATCH_COLS_CMD):
                frames.append(nxt)
            else:
                scalars.append(nxt)
        return frames, scalars, stop

    def serve(self, poll_s: float = 1.0, idle_limit_s: Optional[float] = None) -> int:
        assert self.transport is not None, "serve() needs a transport"
        served = 0
        idle = 0.0
        while True:
            msg = self.transport.pull(poll_s)
            if msg is None:
                idle += poll_s
                if idle_limit_s is not None and idle >= idle_limit_s:
                    return served
                continue
            idle = 0.0
            if msg.get("cmd") == "stop":
                return served
            if msg.get("cmd") in (BATCH_CMD, BATCH_COLS_CMD):
                frames, scalars, stop = self._drain_pending(msg)
                tcs = [TestConfig.from_wire(d)
                       for f in frames for d in unframe_batch(f)]
                # slim wire results: the host rehydrates knobs/arch/shape
                # from its in-flight table, so don't echo them back.  The
                # frame carries one cache_info sidecar — the host scheduler
                # resyncs its per-client cache shadow from it
                self.transport.push_many(
                    [{k: v for k, v in r.items()
                      if k not in ("knobs", "arch", "shape")}
                     for r in self.evaluate_batch(tcs)],
                    extra={"cache_info": self.cache_info()})
                served += len(tcs)
                for m in scalars:   # scalar configs drained behind the frames
                    self.transport.push(self.evaluate(TestConfig.from_wire(m)))
                    served += 1
                if stop:
                    return served
                continue
            result = self.evaluate(TestConfig.from_wire(msg))
            self.transport.push(result)
            served += 1
