"""JClient — the device-side worker (paper §III, Algorithm 1).

Capabilities, mirroring the paper:
  1. configure the device + workload from a received testConfig (JConfig);
  2. measure (JMeasure set, enable/disable at construction);
  3. communicate with the host (any ClientTransport).

The workload is injected as ``build_fn(TestConfig) -> (Artifact, meta)`` —
"the workloads can be anything as JExplore is agnostic to the workload".
Compiled artifacts are cached by the sw-knob fingerprint, the analogue of the
network staying resident on a Jetson while only clocks change.
"""
from __future__ import annotations

import time
import traceback
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core.jconfig import JConfig, TestConfig
from repro.core.jmeasure import DEFAULT_MEASURES, JMeasure
from repro.core.transport import ClientTransport
from repro.roofline.analysis import Artifact

BuildResult = Tuple[Artifact, Dict]


class JClient:
    def __init__(self, jconfig: JConfig,
                 build_fn: Callable[[TestConfig], BuildResult],
                 measures: Sequence[JMeasure] = DEFAULT_MEASURES,
                 transport: Optional[ClientTransport] = None,
                 client_id: int = 0,
                 cache_size: int = 64):
        self.jconfig = jconfig
        self.build_fn = build_fn
        self.measures = tuple(measures)
        self.transport = transport
        self.client_id = client_id
        self._cache: Dict[tuple, BuildResult] = {}
        self._cache_size = cache_size
        self.n_evaluated = 0
        self.n_compiled = 0

    # -- single evaluation -------------------------------------------------
    def evaluate(self, tc: TestConfig) -> dict:
        t0 = time.monotonic()
        key = self.jconfig.cache_key(tc)
        cached = key in self._cache
        try:
            if not cached:
                if len(self._cache) >= self._cache_size:
                    self._cache.pop(next(iter(self._cache)))
                self._cache[key] = self.build_fn(tc)
                self.n_compiled += 1
            art, meta = self._cache[key]
            hw = self.jconfig.hw_model(tc.knobs)
            metrics: Dict[str, float] = {}
            for m in self.measures:
                metrics.update(m.measure(art, hw, meta))
            status = "ok"
        except Exception:
            metrics = {}
            status = "failed"
            metrics["error"] = traceback.format_exc(limit=3)
        self.n_evaluated += 1
        return {
            "config_id": tc.config_id,
            "arch": tc.arch,
            "shape": tc.shape,
            "knobs": tc.knobs,
            "metrics": metrics,
            "status": status,
            "client_id": self.client_id,
            "cached": cached,
            "wall_s": time.monotonic() - t0,
        }

    # -- Algorithm 1, JCLIENT procedure ---------------------------------------
    def serve(self, poll_s: float = 1.0, idle_limit_s: Optional[float] = None) -> int:
        assert self.transport is not None, "serve() needs a transport"
        served = 0
        idle = 0.0
        while True:
            msg = self.transport.pull(poll_s)
            if msg is None:
                idle += poll_s
                if idle_limit_s is not None and idle >= idle_limit_s:
                    return served
                continue
            idle = 0.0
            if msg.get("cmd") == "stop":
                return served
            result = self.evaluate(TestConfig.from_wire(msg))
            self.transport.push(result)
            served += 1
