"""JClient — the device-side worker (paper §III, Algorithm 1).

Capabilities, mirroring the paper:
  1. configure the device + workload from a received testConfig (JConfig);
  2. measure (JMeasure set, enable/disable at construction);
  3. communicate with the host (any ClientTransport).

The workload is injected as ``build_fn(TestConfig) -> (Artifact, meta)`` —
"the workloads can be anything as JExplore is agnostic to the workload".
Compiled artifacts are cached by the sw-knob fingerprint, the analogue of the
network staying resident on a Jetson while only clocks change.  The cache is
a true LRU: a hit refreshes the key, so hot sw-points survive long sweeps
that touch more unique fingerprints than ``cache_size``.

Persistent artifact cache (``cache_dir``)
-----------------------------------------
With ``cache_dir`` set, the in-memory LRU becomes the *hot tier* of a
two-tier cache: every freshly built ``BuildResult`` is also pickled to disk,
content-addressed, and an in-memory miss tries the disk tier before calling
``build_fn`` — the analogue of an on-disk TensorRT engine cache, so a
restarted client (or a repeated sweep) skips the compile entirely for every
fingerprint it has ever built.

Layout: ``<cache_dir>/<hh>/<hash>.pkl`` where ``hash`` is the SHA-256 of
``repr((JConfig.identity(), cache_key))`` and ``hh`` its first two hex
chars (keeps directories small on big sweeps).  Each file holds
``{"v": _DISK_CACHE_VERSION, "key": repr(cache_key), "built": BuildResult}``
written atomically: the payload goes to a uniquely-suffixed temp file
(mkstemp + pid suffix, so two processes sharing one ``--cache-dir`` can
never interleave into one temp file) and lands via ``os.replace``.
Readers therefore never see a torn file on a POSIX filesystem; on
filesystems with weaker rename semantics (NFS) an unreadable read is
retried once after a short sleep — the concurrent writer has usually
finished by then — and only then counted as a miss.

Fleet tier (``fleet_mode``)
---------------------------
With ``fleet_mode`` set (``"serve"`` | ``"relay"``) and a transport
attached, a miss in *both* local tiers asks the fleet before compiling:
the client pushes an ``ARTIFACT_QUERY`` (same content address as the disk
tier) up its result socket and briefly blocks for the host's reply —
a pickled ``BuildResult`` blob from a peer (hit: unpickle, adopt into both
local tiers), or ``ARTIFACT_MISS`` (this client is now the fingerprint's
designated compiler — build, then announce).  In ``serve`` mode the
announcement carries the blob (the host caches and serves it); in
``relay`` mode it is residency-only and the host relays an
``ARTIFACT_FETCH`` back here when a peer needs it.  Config frames that
arrive while the client waits are backlogged and evaluated afterwards, so
the fleet wait never drops work.  See ``repro.core.fleet``.

Invalidation rules: the address covers everything that determines the
artifact — the jconfig identity (design-space knob names/values/kinds +
``n_chips``) and the full ``cache_key`` (arch, shape, sw-knob values) — so
changing any of those naturally misses.  What the address *cannot* see is
the body of ``build_fn`` itself: if the workload builder changes
behaviourally, bump ``_DISK_CACHE_VERSION`` or delete the directory.  A
corrupt/unreadable/version-mismatched file is treated as a miss and
overwritten; entries are never aged out automatically.

``cache_info()`` reports both tiers, and ``serve`` attaches the summary to
every chunk reply (one ``cache_info`` sidecar per result frame) — the
host's ``DispatchScheduler`` uses it to keep its per-client cache shadow
honest for compile-affinity placement.

Batched fast path (group-by-compile)
------------------------------------
``evaluate_batch`` is the throughput-oriented entry point.  It groups the
incoming configs by their sw-knob fingerprint (``JConfig.cache_key``),
compiles each unique sw-group **once**, then sweeps every hw-knob variant of
the group through the vectorized measurement path
(``JMeasure.measure_batch`` over an ``HwModelBatch`` of ``(N,)`` ladder
arrays).  Compile work is therefore O(unique sw-points) instead of
O(configs), and per-config Python/dict overhead collapses into a handful of
numpy sweeps — metrics stay bit-identical to the scalar ``evaluate`` path.
``serve`` speaks both wire formats: a plain testConfig message is evaluated
scalar; a ``{"cmd": "batch", "items": [...]}`` frame (see transport.py) runs
``evaluate_batch`` and pushes one batched result frame back.  Under a
double-buffering host (``dispatch="pipelined"``) several chunks may already
be sitting in the transport queue when the client wakes up — ``serve``
drains every queued batch frame first and coalesces them into a **single**
``evaluate_batch`` call, so speculative chunks share one group-by-compile
sweep and come back as one result frame.
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
import time
import traceback
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jconfig import JConfig, TestConfig
from repro.core.jmeasure import DEFAULT_MEASURES, JMeasure
from repro.core.transport import (ARTIFACT_CHUNK, ARTIFACT_CMDS,
                                  ARTIFACT_FETCH, ARTIFACT_MISS,
                                  ARTIFACT_PUT, ARTIFACT_QUERY, BATCH_CMD,
                                  BATCH_COLS_CMD, ChunkAssembler,
                                  ClientTransport, chunk_blob, unframe_batch)
from repro.roofline.analysis import Artifact

BuildResult = Tuple[Artifact, Dict]

# bump when BuildResult semantics change behaviourally for the same address
# (the content hash cannot see the body of build_fn)
_DISK_CACHE_VERSION = 1

_FLEET_MODES = (None, "serve", "relay")


class JClient:
    def __init__(self, jconfig: JConfig,
                 build_fn: Callable[[TestConfig], BuildResult],
                 measures: Sequence[JMeasure] = DEFAULT_MEASURES,
                 transport: Optional[ClientTransport] = None,
                 client_id: int = 0,
                 cache_size: int = 64,
                 cache_dir: Optional[str] = None,
                 fleet_mode: Optional[str] = None,
                 fleet_timeout_s: float = 30.0,
                 fleet_chunk_bytes: int = 1 << 20):
        if fleet_mode not in _FLEET_MODES:
            raise ValueError(f"fleet_mode must be one of {_FLEET_MODES}, "
                             f"got {fleet_mode!r}")
        self.jconfig = jconfig
        self.build_fn = build_fn
        self.measures = tuple(measures)
        self.transport = transport
        self.client_id = client_id
        self._cache: Dict[tuple, BuildResult] = {}
        self._cache_size = cache_size
        self._cache_hits = 0
        self._cache_misses = 0
        self._cache_evictions = 0
        self.cache_dir = cache_dir
        self._disk_hits = 0
        self._disk_misses = 0
        self._disk_stores = 0
        self.fleet_mode = fleet_mode
        self.fleet_timeout_s = fleet_timeout_s
        self.fleet_chunk_bytes = fleet_chunk_bytes
        self._fleet_hits = 0
        self._fleet_misses = 0
        self._fleet_puts = 0
        self._fleet_bytes_in = 0
        self._fleet_bytes_out = 0
        self._fleet_rx = ChunkAssembler()
        self._addr_key: Dict[str, tuple] = {}   # content addr -> cache_key
        self._rx_backlog: List[dict] = []       # frames deferred by a wait
        # keys a prefetch wave already got ARTIFACT_MISS for: this client
        # is their designated compiler, _artifact must not re-query
        self._fleet_skip: set = set()
        self.n_evaluated = 0
        self.n_compiled = 0
        if cache_dir is not None:
            os.makedirs(cache_dir, exist_ok=True)

    # -- persistent tier (content-addressed pickles, see module docstring) ----
    def _addr(self, key: tuple) -> str:
        """Content address shared by the disk tier and the fleet store."""
        return hashlib.sha256(
            repr((self.jconfig.identity(), key)).encode("utf-8")).hexdigest()

    def _disk_path(self, key: tuple) -> str:
        h = self._addr(key)
        return os.path.join(self.cache_dir, h[:2], h + ".pkl")

    def _disk_load(self, key: tuple) -> Optional[BuildResult]:
        """Read-validate a disk entry; an unreadable file is retried once.

        A concurrent writer sharing this ``cache_dir`` can expose a torn
        or mid-rename read on filesystems without atomic-replace semantics;
        by the retry (5 ms later) the replace has almost always landed.  A
        *cleanly* read entry that fails validation (version bump, hash
        collision) is a deterministic miss — no retry.
        """
        path = self._disk_path(key)
        for attempt in (0, 1):
            try:
                with open(path, "rb") as f:
                    payload = pickle.load(f)
            except FileNotFoundError:
                return None               # plain miss
            except Exception:
                if attempt == 0:          # torn read: writer mid-flight?
                    time.sleep(0.005)
                    continue
                return None
            if (isinstance(payload, dict)
                    and payload.get("v") == _DISK_CACHE_VERSION
                    and payload.get("key") == repr(key)):
                return payload["built"]
            return None
        return None

    def _disk_store(self, key: tuple, built: BuildResult) -> None:
        """Best-effort atomic write; an unpicklable artifact (live device
        buffers, etc.) simply stays memory-only.  The tmp name comes from
        mkstemp *plus a pid suffix*: unique per process and per call, so
        concurrent writers — threads in one process or separate processes
        sharing one ``--cache-dir`` — can never interleave into one file,
        and a crashed writer's orphan is identifiable."""
        path = self._disk_path(key)
        tmp = None
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                       suffix=f".{os.getpid()}.tmp")
            with os.fdopen(fd, "wb") as f:
                pickle.dump({"v": _DISK_CACHE_VERSION, "key": repr(key),
                             "built": built}, f)
            os.replace(tmp, path)
            self._disk_stores += 1
        except Exception:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    # -- artifact cache (LRU hot tier keyed by sw fingerprint) ----------------
    def _artifact(self, key: tuple, tc: TestConfig) -> BuildResult:
        if key in self._cache:
            self._cache[key] = self._cache.pop(key)  # refresh: true LRU
            self._cache_hits += 1
            return self._cache[key]
        self._cache_misses += 1
        built = None
        if self.cache_dir is not None:
            built = self._disk_load(key)
            if built is not None:
                self._disk_hits += 1
            else:
                self._disk_misses += 1
        fetched = False
        if built is None and self.fleet_mode is not None \
                and self.transport is not None:
            if key in self._fleet_skip:
                # a prefetch wave already asked and this client was made
                # the designated compiler (miss counted there): build
                self._fleet_skip.discard(key)
            else:
                built = self._fleet_fetch(key)
                fetched = built is not None
                if fetched:
                    self._fleet_hits += 1
                else:
                    self._fleet_misses += 1
        if built is None:
            built = self.build_fn(tc)
            self.n_compiled += 1
            if self.cache_dir is not None:
                self._disk_store(key, built)
            if self.fleet_mode is not None and self.transport is not None:
                self._fleet_announce(key, built)
        elif fetched and self.cache_dir is not None:
            self._disk_store(key, built)    # adopt the peer's blob locally
        self._cache_insert(key, built)
        return built

    def _cache_insert(self, key: tuple, built: BuildResult) -> None:
        if key in self._cache:
            self._cache[key] = self._cache.pop(key)
            return
        if len(self._cache) >= self._cache_size:
            self._cache.pop(next(iter(self._cache)))  # least-recently used
            self._cache_evictions += 1
        self._cache[key] = built

    def cache_info(self) -> Dict[str, int]:
        """functools-style counters for the artifact cache, all tiers."""
        info = {"hits": self._cache_hits, "misses": self._cache_misses,
                "evictions": self._cache_evictions,
                "currsize": len(self._cache), "maxsize": self._cache_size}
        if self.cache_dir is not None:
            info.update({"disk_hits": self._disk_hits,
                         "disk_misses": self._disk_misses,
                         "disk_stores": self._disk_stores})
        if self.fleet_mode is not None:
            info.update({"fleet_hits": self._fleet_hits,
                         "fleet_misses": self._fleet_misses,
                         "fleet_puts": self._fleet_puts,
                         "fleet_bytes_in": self._fleet_bytes_in,
                         "fleet_bytes_out": self._fleet_bytes_out})
        return info

    # -- fleet tier (host-mediated peer cache, see repro.core.fleet) ----------
    def _payload_blob(self, key: tuple, built: BuildResult) -> Optional[bytes]:
        """The disk-tier payload, pickled — the unit the fleet moves."""
        try:
            return pickle.dumps({"v": _DISK_CACHE_VERSION, "key": repr(key),
                                 "built": built})
        except Exception:
            return None       # live device buffers etc.: memory-only

    def _accept_blob(self, key: tuple, msg: dict) -> Optional[BuildResult]:
        blob = msg.get("blob")
        if not isinstance(blob, (bytes, bytearray)):
            return None
        self._fleet_bytes_in += len(blob)
        try:
            payload = pickle.loads(bytes(blob))
        except Exception:
            return None
        if (isinstance(payload, dict)
                and payload.get("v") == _DISK_CACHE_VERSION
                and payload.get("key") == repr(key)):
            return payload["built"]
        return None

    def _fleet_fetch(self, key: tuple) -> Optional[BuildResult]:
        """Query the host for a peer's artifact; block up to
        ``fleet_timeout_s`` for the verdict.  Any non-matching frame pulled
        while waiting (queued config chunks, other artifact traffic) is
        backlogged for ``serve`` to process afterwards — the wait never
        drops work."""
        addr = self._addr(key)
        self._addr_key[addr] = key
        try:
            self.transport.push({"cmd": ARTIFACT_QUERY, "addr": addr,
                                 "fp": repr(key),
                                 "client_id": self.client_id})
        except Exception:
            return None
        deadline = time.monotonic() + self.fleet_timeout_s
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            msg = self.transport.pull(min(remaining, 0.05))
            if msg is None:
                continue
            cmd = msg.get("cmd")
            if cmd == ARTIFACT_CHUNK and msg.get("addr") == addr:
                done = self._fleet_rx.feed(msg)
                if done is None:
                    continue
                msg, cmd = done, ARTIFACT_PUT
            if cmd == ARTIFACT_PUT and msg.get("addr") == addr:
                return self._accept_blob(key, msg)
            if cmd == ARTIFACT_MISS and msg.get("addr") == addr:
                if msg.get("spec"):
                    continue      # stale passive reply: not an assignment
                return None       # this client is the designated compiler
            if cmd in ARTIFACT_CMDS:
                # other artifact traffic is handled INLINE, not backlogged:
                # a relayed ARTIFACT_FETCH for an artifact this client holds
                # must be answered now — two clients each waiting on a blob
                # the other one holds would otherwise deadlock until their
                # fleet timeouts (serving a fetch only reads local tiers,
                # so it cannot recurse into another fleet wait)
                self._on_artifact(msg)
                continue
            self._rx_backlog.append(msg)

    def _fleet_prefetch(self, keys: Sequence[tuple]) -> None:
        """Pipeline fleet queries for every fingerprint an incoming batch
        needs but no local tier holds: one wave of ``ARTIFACT_QUERY``s,
        then one collect loop — k fetches cost ~one host round trip
        instead of k serial ones.

        Prefetch queries are *passive* (``spec: True``): the host serves a
        cached blob or parks us in a waiter list, but never assigns
        compile duty (that would pile several fingerprints' compiles onto
        whichever client's wave lands first) and always answers at once —
        a ``spec`` MISS means "nothing to serve yet, move on", after which
        the per-group ``_fleet_fetch`` does the active query.  Blobs that
        arrive after the wave (an in-flight compile we joined as waiter)
        are adopted by ``_on_artifact``.
        """
        want: Dict[str, tuple] = {}
        for key in keys:
            if key in self._cache or key in self._fleet_skip:
                continue
            if self.cache_dir is not None \
                    and os.path.exists(self._disk_path(key)):
                continue                   # the disk tier will hit
            addr = self._addr(key)
            self._addr_key[addr] = key
            want[addr] = key
        if not want:
            return
        try:
            for addr, key in want.items():
                self.transport.push({"cmd": ARTIFACT_QUERY, "addr": addr,
                                     "fp": repr(key), "spec": True,
                                     "client_id": self.client_id})
        except Exception:
            return
        outstanding = set(want)
        deadline = time.monotonic() + self.fleet_timeout_s
        while outstanding:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return
            msg = self.transport.pull(min(remaining, 0.05))
            if msg is None:
                continue
            cmd = msg.get("cmd")
            addr = msg.get("addr")
            if cmd == ARTIFACT_CHUNK and addr in outstanding:
                done = self._fleet_rx.feed(msg)
                if done is None:
                    continue
                msg, cmd = done, ARTIFACT_PUT
            if cmd == ARTIFACT_PUT and addr in outstanding:
                outstanding.discard(addr)
                key = want[addr]
                built = self._accept_blob(key, msg)
                if built is not None:
                    self._fleet_hits += 1
                    if self.cache_dir is not None:
                        self._disk_store(key, built)
                    self._cache_insert(key, built)
            elif cmd == ARTIFACT_MISS and addr in outstanding:
                outstanding.discard(addr)
                if not msg.get("spec"):
                    # a stale *active* MISS: we hold compile duty for it
                    self._fleet_misses += 1
                    self._fleet_skip.add(want[addr])
                    return   # compile duty first; peers are waiting on us
            elif cmd in ARTIFACT_CMDS:
                self._on_artifact(msg)   # incl. relayed fetches: see above
            else:
                self._rx_backlog.append(msg)

    def _fleet_announce(self, key: tuple, built: BuildResult) -> None:
        """Tell the host about a fresh compile: blob attached in ``serve``
        mode, residency-only in ``relay`` mode."""
        addr = self._addr(key)
        self._addr_key[addr] = key
        base = {"addr": addr, "fp": repr(key), "client_id": self.client_id}
        try:
            if self.fleet_mode == "serve":
                blob = self._payload_blob(key, built)
                if blob is None:
                    return
                self._fleet_bytes_out += len(blob)
                for frame in chunk_blob(base, blob, self.fleet_chunk_bytes):
                    self.transport.push(frame)
            else:
                self.transport.push(dict(base, cmd=ARTIFACT_PUT))
            self._fleet_puts += 1
        except Exception:
            pass              # announcements are best-effort

    def _on_artifact(self, msg: dict) -> None:
        """Handle an artifact frame outside a fetch wait: relay-mode fetch
        requests, and late/prefetch PUTs (adopted into the local tiers)."""
        cmd = msg.get("cmd")
        if cmd == ARTIFACT_CHUNK:
            done = self._fleet_rx.feed(msg)
            if done is None:
                return
            msg, cmd = done, ARTIFACT_PUT
        addr = msg.get("addr")
        if cmd == ARTIFACT_FETCH and isinstance(addr, str):
            self._serve_fetch(addr)
        elif cmd == ARTIFACT_PUT and isinstance(addr, str):
            key = self._addr_key.get(addr)
            if key is None or key in self._cache:
                return
            built = self._accept_blob(key, msg)
            if built is not None:
                self._fleet_hits += 1
                if self.cache_dir is not None:
                    self._disk_store(key, built)
                self._cache_insert(key, built)
        # stray ARTIFACT_MISS frames (e.g. after a timed-out wait): ignore

    def _serve_fetch(self, addr: str) -> None:
        """Relay mode: the host asks for a blob this client supposedly
        holds.  Serve it from LRU or disk; apologize with ``gone`` if both
        tiers lost it (the host drops the residency claim)."""
        key = self._addr_key.get(addr)
        built = None
        if key is not None:
            built = self._cache.get(key)
            if built is None and self.cache_dir is not None:
                built = self._disk_load(key)
        blob = self._payload_blob(key, built) if built is not None else None
        base = {"addr": addr, "client_id": self.client_id}
        if key is not None:
            base["fp"] = repr(key)
        try:
            if blob is None:
                self.transport.push(dict(base, cmd=ARTIFACT_PUT,
                                         status="gone"))
                return
            self._fleet_bytes_out += len(blob)
            self._fleet_puts += 1
            for frame in chunk_blob(base, blob, self.fleet_chunk_bytes):
                self.transport.push(frame)
        except Exception:
            pass

    # -- single evaluation -------------------------------------------------
    def evaluate(self, tc: TestConfig) -> dict:
        t0 = time.monotonic()
        key = self.jconfig.cache_key(tc)
        cached = key in self._cache
        try:
            art, meta = self._artifact(key, tc)
            hw = self.jconfig.hw_model(tc.knobs)
            metrics: Dict[str, float] = {}
            for m in self.measures:
                metrics.update(m.measure(art, hw, meta))
            status = "ok"
        except Exception:
            metrics = {}
            status = "failed"
            metrics["error"] = traceback.format_exc(limit=3)
        self.n_evaluated += 1
        return {
            "config_id": tc.config_id,
            "arch": tc.arch,
            "shape": tc.shape,
            "knobs": tc.knobs,
            "metrics": metrics,
            "status": status,
            "client_id": self.client_id,
            "cached": cached,
            "wall_s": time.monotonic() - t0,
        }

    # -- batched evaluation (group-by-compile) --------------------------------
    def evaluate_batch(self, tcs: Sequence[TestConfig]) -> List[dict]:
        """Evaluate a batch with one compile per unique sw fingerprint.

        Result dicts are ordered like ``tcs`` and carry exactly the scalar
        ``evaluate`` schema; metric values are bit-identical to N scalar
        calls (the vectorized sweep mirrors the scalar arithmetic op-for-op).
        """
        results: List[Optional[dict]] = [None] * len(tcs)
        groups: Dict[tuple, List[int]] = {}
        for i, tc in enumerate(tcs):
            groups.setdefault(self.jconfig.cache_key(tc), []).append(i)
        if self.fleet_mode is not None and self.transport is not None:
            self._fleet_prefetch(list(groups))

        for key, idxs in groups.items():
            g0 = time.monotonic()
            was_cached = key in self._cache
            cols: Dict[str, np.ndarray] = {}
            try:
                art, meta = self._artifact(key, tcs[idxs[0]])
                hwb = self.jconfig.hw_model_batch([tcs[i].knobs for i in idxs])
                for m in self.measures:
                    cols.update(m.measure_batch(art, hwb, meta))
            except Exception:
                # scalar-parity fallback: a group-level failure (bad build, or
                # one hw variant tripping a measure) must not fail sibling
                # configs that would survive the scalar path — re-evaluate the
                # group one config at a time
                for i in idxs:
                    results[i] = self.evaluate(tcs[i])
                    self.n_evaluated -= 1   # evaluate() counted it; the batch
                    # total is added once at the end for all of tcs
                continue
            # one C-level tolist per metric column beats N×K .item() calls
            names = list(cols)
            rows = [np.asarray(cols[k]).tolist() for k in names]
            wall = (time.monotonic() - g0) / len(idxs)  # amortized per config
            for j, i in enumerate(idxs):
                tc = tcs[i]
                results[i] = {
                    "config_id": tc.config_id,
                    "arch": tc.arch,
                    "shape": tc.shape,
                    "knobs": tc.knobs,
                    "metrics": {k: col[j] for k, col in zip(names, rows)},
                    "status": "ok",
                    "client_id": self.client_id,
                    # sequential-scalar parity: the group's first config pays
                    # the compile, the rest ride the cache
                    "cached": was_cached or j > 0,
                    "wall_s": wall,
                }
        self.n_evaluated += len(tcs)
        return results  # type: ignore[return-value]

    # -- Algorithm 1, JCLIENT procedure ---------------------------------------
    def _pull(self, timeout: float) -> Optional[dict]:
        """Transport pull that honours the fleet-wait backlog: frames
        deferred by ``_fleet_fetch`` come back first, in arrival order."""
        if self._rx_backlog:
            return self._rx_backlog.pop(0)
        return self.transport.pull(timeout)

    def _drain_pending(self, first: dict):
        """Coalesce every already-queued batch frame behind ``first``.

        A pipelined host keeps ≥2 chunks in this client's queue; evaluating
        them as one batch shares the group-by-compile sweep.  Returns
        (batch_frames, scalar_msgs, stop_seen) in arrival order.  Artifact
        frames are handled inline (they carry no work to evaluate).
        """
        frames, scalars, stop = [first], [], False
        while True:
            nxt = self._pull(0.0)
            if nxt is None:
                break
            cmd = nxt.get("cmd")
            if cmd == "stop":
                stop = True
                break
            if cmd in (BATCH_CMD, BATCH_COLS_CMD):
                frames.append(nxt)
            elif cmd in ARTIFACT_CMDS:
                self._on_artifact(nxt)
            else:
                scalars.append(nxt)
        return frames, scalars, stop

    def serve(self, poll_s: float = 1.0, idle_limit_s: Optional[float] = None) -> int:
        assert self.transport is not None, "serve() needs a transport"
        served = 0
        idle = 0.0
        while True:
            msg = self._pull(poll_s)
            if msg is None:
                idle += poll_s
                if idle_limit_s is not None and idle >= idle_limit_s:
                    return served
                continue
            idle = 0.0
            if msg.get("cmd") == "stop":
                return served
            if msg.get("cmd") in ARTIFACT_CMDS:
                self._on_artifact(msg)
                continue
            if msg.get("cmd") in (BATCH_CMD, BATCH_COLS_CMD):
                frames, scalars, stop = self._drain_pending(msg)
                tcs = [TestConfig.from_wire(d)
                       for f in frames for d in unframe_batch(f)]
                # slim wire results: the host rehydrates knobs/arch/shape
                # from its in-flight table, so don't echo them back.  The
                # frame carries one cache_info sidecar — the host scheduler
                # resyncs its per-client cache shadow from it
                self.transport.push_many(
                    [{k: v for k, v in r.items()
                      if k not in ("knobs", "arch", "shape")}
                     for r in self.evaluate_batch(tcs)],
                    extra={"cache_info": self.cache_info()})
                served += len(tcs)
                for m in scalars:   # scalar configs drained behind the frames
                    self.transport.push(self.evaluate(TestConfig.from_wire(m)))
                    served += 1
                if stop:
                    return served
                continue
            result = self.evaluate(TestConfig.from_wire(msg))
            self.transport.push(result)
            served += 1
