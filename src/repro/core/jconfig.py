"""JConfig — configuration management (paper §III).

Turns a design-point dict into everything the client needs to apply it:
  * ``BuildFlags``  — the HLO-affecting (sw) subset
  * mesh factorisation (dp, tp)
  * ``HwModel``     — the hardware-ladder (hw) subset
  * ``cache_key``   — hashable sw fingerprint; JClient re-uses the compiled
    artifact when only hw knobs changed (the analogue of Jetson re-clocking
    without touching the deployed network).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.space import DesignSpace, KIND_SW
from repro.models.model import BuildFlags
from repro.roofline.hw import HwModel, HwModelBatch


@dataclasses.dataclass(frozen=True)
class TestConfig:
    """One unit of work pushed host → client (Algorithm 1's testConfig)."""
    config_id: int
    arch: str
    shape: str
    knobs: Dict[str, Any]

    def to_wire(self) -> dict:
        return {"config_id": self.config_id, "arch": self.arch,
                "shape": self.shape, "knobs": self.knobs}

    @staticmethod
    def from_wire(d: dict) -> "TestConfig":
        return TestConfig(d["config_id"], d["arch"], d["shape"], d["knobs"])


TestConfig.__test__ = False  # not a pytest class


class JConfig:
    def __init__(self, space: DesignSpace, n_chips: int = 256):
        self.space = space
        self.n_chips = n_chips
        # sorted once: cache_key is on the batched hot path (once per config)
        self._sw_names = tuple(sorted(
            k.name for k in space if k.kind == KIND_SW))

    def build_flags(self, knobs: Dict[str, Any]) -> BuildFlags:
        kw = {}
        for f in ("dtype", "remat", "loss_chunks", "attn_block_q",
                  "attn_block_kv", "sp", "fsdp", "grad_rs"):
            if f in knobs:
                kw[f] = knobs[f]
        return BuildFlags(**kw)

    def mesh_factors(self, knobs: Dict[str, Any]) -> Tuple[int, int]:
        dp = int(knobs.get("dp_degree", 16))
        assert self.n_chips % dp == 0, (dp, self.n_chips)
        return dp, self.n_chips // dp

    def microbatch(self, knobs: Dict[str, Any]) -> int:
        return int(knobs.get("microbatch", 1))

    def ssd_chunk(self, knobs: Dict[str, Any]) -> Optional[int]:
        return knobs.get("ssd_chunk")

    def hw_model(self, knobs: Dict[str, Any]) -> HwModel:
        return HwModel(
            n_chips=self.n_chips,
            clock_scale=float(knobs.get("clock_scale", 1.0)),
            hbm_scale=float(knobs.get("hbm_scale", 1.0)),
            ici_scale=float(knobs.get("ici_scale", 1.0)),
            dtype=str(knobs.get("dtype", "bfloat16")),
        )

    def hw_model_batch(self, knobs_seq: Sequence[Dict[str, Any]]) -> HwModelBatch:
        """Vectorized ``hw_model`` over configs sharing a sw fingerprint.

        ``dtype`` is a sw knob, so within one cache-key group it is uniform —
        the batch takes it from the first member.
        """
        return HwModelBatch(
            self.n_chips,
            np.asarray([float(k.get("clock_scale", 1.0)) for k in knobs_seq]),
            np.asarray([float(k.get("hbm_scale", 1.0)) for k in knobs_seq]),
            np.asarray([float(k.get("ici_scale", 1.0)) for k in knobs_seq]),
            dtype=str(knobs_seq[0].get("dtype", "bfloat16")))

    def cache_key(self, tc: TestConfig) -> Tuple:
        """Fingerprint of everything that changes the compiled artifact."""
        knobs = tc.knobs
        # knob names are unique, so name-sorted pairs == sorted pairs
        sw = tuple((n, knobs[n]) for n in self._sw_names if n in knobs)
        return (tc.arch, tc.shape, sw)

    def identity(self) -> Tuple:
        """Stable fingerprint of this configuration manager itself — the
        design space (names, value sets, kinds) and the chip count.  The
        persistent artifact cache addresses entries by ``(identity(),
        cache_key(tc))``, so artifacts built under a different space or
        fleet shape can never be served by mistake."""
        return ("jconfig-v1", self.n_chips,
                tuple((k.name, k.kind, tuple(repr(v) for v in k.values))
                      for k in self.space))
