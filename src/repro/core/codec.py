"""Pluggable wire codecs for host↔client frames.

``transport.py`` frames chunks of testConfigs/results into single messages
(row ``batch`` frames or columnar ``batchc`` frames — see there); this module
decides how a framed dict becomes bytes on the wire:

* ``JsonCodec``   — UTF-8 JSON, the seed protocol.  Interoperates with any
  peer (including ``send_json``/``recv_json`` ZMQ code).
* ``BinaryCodec`` — a compact self-describing container that lifts every
  *uniformly-typed numeric column* (the dominant payload of a columnar
  ``batchc`` frame: config_id lists, hw-ladder knob columns, metric columns)
  out of the JSON body and packs it as a little-endian typed array
  (int64 / float64 / uint8-bool).  Strings and mixed columns stay in the
  JSON skeleton, so the codec is lossless and type-exact: ints stay ints,
  floats round-trip bit-for-bit (no decimal text detour), bools stay bools.
  A message with nothing to pack degenerates to plain JSON bytes.

Bytes payloads (artifact blobs)
-------------------------------
The fleet artifact store ships pickled ``BuildResult`` blobs inside
``artifact_put``/``artifact_chunk`` frames (see ``core.transport``).  Under
``BinaryCodec`` a ``bytes`` value (tag ``"y"``) or a uniform list of
``bytes`` (tag ``"Y"``, per-element length table) is carried as a raw blob
segment appended after the JSON header — zero copies through text,
no base64 inflation.  ``JsonCodec`` cannot carry raw bytes in a JSON
document, so it falls back to a tagged base64 wrapper
(``{"__b64__": "..."}``) that ``decode_wire`` transparently unwraps: a
JSON-configured fleet still moves blobs correctly, it just pays the ~33%
base64 tax the binary codec avoids.  (A user payload dict whose *only* key
is literally ``__b64__`` would be mangled by the unwrap; no frame in this
protocol has that shape.)

Wire negotiation
----------------
Binary frames start with a magic prefix that is invalid as leading JSON
(0x93), so ``decode_wire`` can always sniff which codec produced a payload —
every transport in this repo decodes with it, which makes a binary host
readable by a JSON client and vice versa with **zero** configuration on the
receive path.  On the send path, client transports answer in the codec of
the last frame they received (``sniff_codec``): a binary host gets binary
result frames back, a JSON host gets JSON, regardless of how the client was
configured.  The host always speaks its configured codec (it initiates).
"""
from __future__ import annotations

import base64
import json
import struct
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

# 0x93 cannot begin a JSON document, so the prefix is unambiguous
MAGIC = b"\x93JXB1"
_INT64_MIN, _INT64_MAX = -(2 ** 63), 2 ** 63 - 1

# column type tags -> (numpy dtype, bytes per element); bytes payloads use
# the separate "y" (scalar) / "Y" (column) tags with explicit lengths
_DTYPES = {"i": ("<i8", 8), "f": ("<f8", 8), "b": ("u1", 1)}


def _json_default(obj):
    """JSON fallback for ``bytes``: tagged base64 (see module docstring)."""
    if isinstance(obj, (bytes, bytearray)):
        return {"__b64__": base64.b64encode(bytes(obj)).decode("ascii")}
    raise TypeError(f"not JSON serializable: {type(obj).__name__}")


def _json_object_hook(d: dict):
    if len(d) == 1 and "__b64__" in d and isinstance(d["__b64__"], str):
        return base64.b64decode(d["__b64__"])
    return d


def _column_type(vals: list) -> Optional[str]:
    """Type tag if ``vals`` is a packable uniform scalar column, else None."""
    if not vals:
        return None
    t0 = type(vals[0])
    if t0 is bool:
        return "b" if all(type(v) is bool for v in vals) else None
    if t0 is int:
        if all(type(v) is int and _INT64_MIN <= v <= _INT64_MAX
               for v in vals):
            return "i"
        return None
    if t0 is float:
        return "f" if all(type(v) is float for v in vals) else None
    return None


class Codec:
    """encode() a framed message dict to wire bytes; decode is universal."""

    name: str = "?"

    def encode(self, msg: dict) -> bytes:
        raise NotImplementedError

    def decode(self, data: Union[bytes, str]) -> dict:
        return decode_wire(data)


class JsonCodec(Codec):
    name = "json"

    def encode(self, msg: dict) -> bytes:
        return json.dumps(msg, default=_json_default).encode("utf-8")


class BinaryCodec(Codec):
    name = "binary"

    def encode(self, msg: dict) -> bytes:
        packed: List[dict] = []
        blobs: List[bytes] = []
        skeleton = self._strip(msg, (), packed, blobs)
        if not packed:                  # nothing to pack: plain JSON is fine
            return json.dumps(msg, default=_json_default).encode("utf-8")
        header = json.dumps({"h": skeleton, "p": packed},
                            separators=(",", ":"),
                            default=_json_default).encode("utf-8")
        return b"".join([MAGIC, struct.pack("<I", len(header)), header]
                        + blobs)

    def _strip(self, obj: dict, path: Tuple[str, ...],
               packed: List[dict], blobs: List[bytes]) -> dict:
        """Copy ``obj`` minus packable columns, recording them in order."""
        out: Dict = {}
        for k, v in obj.items():
            if isinstance(v, dict):
                out[k] = self._strip(v, path + (k,), packed, blobs)
                continue
            if isinstance(v, (bytes, bytearray)):      # raw blob segment
                packed.append({"k": list(path) + [k], "t": "y", "n": len(v)})
                blobs.append(bytes(v))
                continue
            if isinstance(v, list):
                if v and all(isinstance(x, (bytes, bytearray)) for x in v):
                    packed.append({"k": list(path) + [k], "t": "Y",
                                   "l": [len(x) for x in v]})
                    blobs.append(b"".join(bytes(x) for x in v))
                    continue
                tag = _column_type(v)
                if tag is not None:
                    dt, _ = _DTYPES[tag]
                    packed.append({"k": list(path) + [k], "t": tag,
                                   "n": len(v)})
                    blobs.append(np.asarray(v, dt).tobytes())
                    continue
            out[k] = v
        return out


def _decode_binary(data: bytes) -> dict:
    (hlen,) = struct.unpack_from("<I", data, len(MAGIC))
    off = len(MAGIC) + 4
    header = json.loads(data[off:off + hlen].decode("utf-8"),
                        object_hook=_json_object_hook)
    off += hlen
    msg = header["h"]
    for ent in header["p"]:
        tag = ent["t"]
        if tag == "y":                       # scalar bytes: raw slice
            n = ent["n"]
            col: object = data[off:off + n]
            off += n
        elif tag == "Y":                     # bytes column: length table
            parts = []
            for ln in ent["l"]:
                parts.append(data[off:off + ln])
                off += ln
            col = parts
        else:
            dt, width = _DTYPES[tag]
            n = ent["n"]
            col = np.frombuffer(data, dt, n, off).tolist()
            off += n * width
            if tag == "b":
                col = [bool(x) for x in col]
        tgt = msg
        for k in ent["k"][:-1]:
            tgt = tgt[k]
        tgt[ent["k"][-1]] = col
    return msg


def decode_wire(data: Union[bytes, bytearray, str]) -> dict:
    """Sniffing decoder: every transport reads both codecs transparently."""
    if isinstance(data, str):
        return json.loads(data, object_hook=_json_object_hook)
    if bytes(data[:len(MAGIC)]) == MAGIC:
        return _decode_binary(bytes(data))
    return json.loads(bytes(data).decode("utf-8"),
                      object_hook=_json_object_hook)


def sniff_codec(data: Union[bytes, bytearray, str]) -> str:
    """Which codec produced this payload ('json' | 'binary')."""
    if not isinstance(data, str) and bytes(data[:len(MAGIC)]) == MAGIC:
        return "binary"
    return "json"


JSON_CODEC = JsonCodec()
BINARY_CODEC = BinaryCodec()
CODECS: Dict[str, Codec] = {c.name: c for c in (JSON_CODEC, BINARY_CODEC)}


def resolve_codec(codec: Union[str, Codec, None]) -> Codec:
    if codec is None:
        return JSON_CODEC
    if isinstance(codec, Codec):
        return codec
    try:
        return CODECS[codec]
    except KeyError:
        raise ValueError(f"unknown codec {codec!r}; "
                         f"choose from {sorted(CODECS)}") from None
