"""Slot-based continuous batching over a shared fixed-capacity KV cache.

``Engine.generate`` serves one whole batch to completion; a production server
instead keeps B slots busy: when a request finishes (EOS or length budget) its
slot is freed and the next queued request is prefilled into it while the other
slots keep decoding.  ``SlotServer`` implements that loop on top of the same
Model prefill/decode functions, using the per-slot position support in
``decode_attention`` (a (B,) position vector: every row writes/attends at its
own causal frontier, so slots at different depths decode in one batch).

Slot hygiene: a freed slot's cache rows are overwritten by the next prefill
on [0, prompt_len) and every later position is re-written by decode before it
enters the attention frontier, so stale rows are never attended.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # prompt token ids (1-D)
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SlotServer:
    """Continuous-batching server with n_slots concurrent sequences."""

    def __init__(self, model, params, n_slots: int, max_len: int,
                 eos_id: Optional[int] = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = model.empty_caches(n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int32)        # next write position
        self.active: List[Optional[Request]] = [None] * n_slots
        self.finished: List[Request] = []
        self._queue: List[Request] = []
        self._next_tok = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(self.model.decode_step)

    # -- prefill one request into one slot of the shared caches ---------------
    def _prefill_into_slot(self, req: Request, slot: int) -> None:
        toks = jnp.asarray(req.tokens[None, :])
        logits, fresh = self.model.prefill(self.params, {"tokens": toks})
        plen = len(req.tokens)

        def put(shared, new):
            """Merge a batch=1 fresh cache leaf into the shared leaf's slot.

            Leaf kinds are identified structurally: attn KV differs from the
            shared leaf in (batch, seq); mamba state/conv differ in batch
            only."""
            diffs = [i for i in range(new.ndim)
                     if shared.shape[i] != new.shape[i]]
            if len(diffs) == 2:                       # attn kv: pad seq, place
                b_ax, s_ax = diffs
                pad = [(0, 0)] * new.ndim
                pad[s_ax] = (0, shared.shape[s_ax] - new.shape[s_ax])
                return jax.lax.dynamic_update_slice_in_dim(
                    shared, jnp.pad(new, pad).astype(shared.dtype), slot,
                    axis=b_ax)
            if len(diffs) == 1:
                return jax.lax.dynamic_update_slice_in_dim(
                    shared, new.astype(shared.dtype), slot, axis=diffs[0])
            return new.astype(shared.dtype)           # n_slots == 1

        self.caches = jax.tree.map(put, self.caches, fresh)
        first = int(jnp.argmax(logits[0]))
        req.out.append(first)
        self.active[slot] = req
        self.pos[slot] = plen
        self._next_tok[slot, 0] = first
        self._maybe_finish(slot)

    def _maybe_finish(self, slot: int) -> None:
        req = self.active[slot]
        tok = req.out[-1]
        if (len(req.out) >= req.max_new
                or (self.eos_id is not None and tok == self.eos_id)
                or self.pos[slot] >= self.max_len - 1):
            req.done = True
            self.finished.append(req)
            self.active[slot] = None

    # -- public API -------------------------------------------------------------
    def submit(self, rid: int, tokens, max_new: int) -> None:
        self._queue.append(Request(rid, np.asarray(tokens, np.int32), max_new))

    def step(self) -> int:
        """Fill free slots, then one decode step for all busy slots."""
        for s in range(self.n_slots):
            if self.active[s] is None and self._queue:
                self._prefill_into_slot(self._queue.pop(0), s)
        busy = [s for s in range(self.n_slots) if self.active[s] is not None]
        if not busy:
            return 0
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self._next_tok), self.caches,
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for s in busy:
            self.active[s].out.append(int(nxt[s]))
            self.pos[s] += 1
            self._next_tok[s, 0] = int(nxt[s])
            self._maybe_finish(s)
        return len(busy)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if self.step() == 0 and not self._queue:
                break
        return self.finished
