from repro.serve.engine import Engine, GenerationResult
from repro.serve.kv_cache import Request, SlotServer
