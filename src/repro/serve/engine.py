"""Serving engine: batched prefill + greedy decode with slot-based KV cache.

Greedy sampling matches the paper's experiments ("we used greedy sampling for
token generation so that all inferences generate the same output") — the
generation workloads explored by JExplore are deterministic.

The engine keeps a fixed-capacity batch of request slots over a shared
max_len cache; finished requests free their slot for the next queued request
(continuous-batching-lite).  ``generate`` is the simple whole-batch API used
by the examples and the paper-reproduction benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class GenerationResult:
    tokens: Any                  # (B, n_gen) np/int32
    n_prompt: int
    n_generated: int


class Engine:
    def __init__(self, model, params, max_len: int, donate: bool = True):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,) if donate else ())
        self._prefill = jax.jit(model.prefill)

    def _pad_caches(self, caches, cur_len: int):
        """Grow prefill caches (seq axis cur_len) to max_len slots."""
        def pad(c):
            if c.ndim >= 3 and c.shape[-3] == cur_len:  # attn (…, S, Hkv, dh)
                widths = [(0, 0)] * c.ndim
                widths[-3] = (0, self.max_len - cur_len)
                return jnp.pad(c, widths)
            return c
        return jax.tree.map(pad, caches)

    def generate(self, batch: Dict[str, Any], n_tokens: int) -> GenerationResult:
        """Greedy-generate n_tokens continuations for the whole batch."""
        prompt_len = (batch["tokens"].shape[1]
                      + (self.model.cfg.n_frontend_tokens if self.model.cfg.frontend == "vision" else 0))
        logits, caches = self._prefill(self.params, batch)
        caches = self._pad_caches(caches, prompt_len)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]

        def step(carry, pos):
            tok, caches = carry
            logits, caches = self._decode_step_inner(tok, caches, pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return (nxt, caches), tok[:, 0]

        # lax.scan keeps the decode loop on-device (one dispatch)
        (last, caches), toks = jax.lax.scan(
            step, (tok, caches), jnp.arange(prompt_len, prompt_len + n_tokens - 1))
        toks = jnp.concatenate([toks.T, last], axis=1)
        return GenerationResult(tokens=jax.device_get(toks),
                                n_prompt=prompt_len, n_generated=n_tokens)

    def _decode_step_inner(self, tok, caches, pos):
        return self.model.decode_step(self.params, tok, caches, pos)
