"""Analytic HBM-traffic model (fusion-aware memory-roofline term).

``compiled.cost_analysis()['bytes accessed']`` on the CPU dry-run backend
counts every HLO op's operands — it does not model the TPU fusion that keeps
elementwise chains in VMEM/registers, so it overstates HBM traffic by ~5-10×
(EXPERIMENTS.md §Roofline shows both).  This module estimates what a fused
TPU execution actually moves through HBM, term by term:

  weights      materialised per device per pass = Ntot·b/tp  (FSDP gathers
               land in HBM once per step regardless of the data-axis shards)
  activations  per-token boundary traffic per layer (matmul inputs/outputs;
               flash-attention score traffic stays in VMEM, but K/V are
               re-read once per q-block)
  optimizer    AdamW: m,v fp32 read+write + fp32 grads r/w;  Adafactor: ~5%
  logits       T·V fp32 write+read (backward)
  KV caches    decode reads the full (sequence-sharded) cache every step;
               prefill writes it once
  MoE decode   only experts actually hit are read: E_touch = E·(1-(1-k/E)^B)

Accuracy target is ±30% — enough to rank bottlenecks and steer the §Perf
hillclimb; exact byte movement requires a real TPU profile.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig


def _dtype_bytes(flags) -> int:
    return 4 if flags.dtype == "float32" else 2


def _layer_token_bytes(arch: ArchConfig, spec, flags, seq_len: int) -> float:
    """Activation HBM bytes per token for one layer (one forward pass)."""
    b = _dtype_bytes(flags)
    d = arch.d_model
    total = 4 * d * b  # residual in/out at both block boundaries
    if spec.mixer in ("attn", "attn_local"):
        h, hkv, dh = arch.n_heads, arch.n_kv_heads, arch.d_head
        total += (2 * h + 2 * hkv) * dh * b          # q,k,v,o tensors
        # flash attention: scores stay in VMEM; K/V re-read once per q block
        window = arch.sliding_window if spec.mixer == "attn_local" else 0
        kv_span = min(window, seq_len) if window else seq_len
        total += (kv_span / max(flags.attn_block_q, 1)) * 2 * hkv * dh * b
    else:
        di, n, hs, ps = arch.d_inner, arch.ssm_state, arch.n_ssm_heads, arch.ssm_head_dim
        total += (2 * di + 2 * (di + 2 * n)) * b     # in_proj out, conv in/out
        total += 2 * di * b                          # gated-norm + out_proj in
        total += 8.0 * hs * ps * n / max(arch.ssm_chunk, 1)  # chunk state r/w f32
    if spec.ffn == "dense":
        f = arch.d_ff if arch.d_ff else arch.moe_d_ff
        total += (2 * d + 2 * f) * b
    elif spec.ffn == "moe":
        k, fe = arch.moe_top_k, arch.moe_d_ff
        total += (2 * k * d + 2 * k * fe) * b        # dispatch/combine + expert h
        if arch.n_shared_experts:
            total += 2 * arch.n_shared_experts * fe * b
    return total


def _weights_bytes(arch: ArchConfig, flags, tp: int, touch_frac: float = 1.0) -> float:
    """Per-device materialised weight bytes for one pass over the model."""
    b = _dtype_bytes(flags)
    from repro.models.model import count_params_analytic

    n_tot = count_params_analytic(arch)
    n_act = count_params_analytic(arch, active_only=True)
    moe_extra = n_tot - n_act
    return (n_act + moe_extra * touch_frac) * b / tp


def _moe_touch_frac(arch: ArchConfig, n_seqs: int) -> float:
    if not arch.n_experts:
        return 1.0
    k, e = arch.moe_top_k, arch.n_experts
    return 1.0 - (1.0 - k / e) ** max(n_seqs, 1)


def analytic_hbm_bytes_per_device(arch: ArchConfig, shape: ShapeConfig, flags,
                                  n_dev: int, dp: int, tp: int,
                                  optimizer: str = "adamw") -> float:
    b = _dtype_bytes(flags)
    from repro.models.model import count_params_analytic

    n_tot = count_params_analytic(arch)
    tokens_dev = shape.global_batch * shape.seq_len / n_dev
    specs = arch.layer_specs()

    if shape.kind == "train":
        remat_extra = 1 if flags.remat in ("full", "selective") else 0
        w = _weights_bytes(arch, flags, tp) * (2 + remat_extra)
        # activation boundary traffic: fwd (+recompute) + bwd ≈ (2+r)×
        act = sum(_layer_token_bytes(arch, s, flags, shape.seq_len) for s in specs)
        act_total = tokens_dev * act * (2 + remat_extra)
        opt = n_tot / n_dev * (24.0 if optimizer == "adamw" else 9.0)
        logits = 2 * tokens_dev * arch.vocab_size * 4
        return w + act_total + opt + logits

    if shape.kind == "prefill":
        w = _weights_bytes(arch, flags, tp)
        act = sum(_layer_token_bytes(arch, s, flags, shape.seq_len) for s in specs)
        cache_write = tokens_dev * sum(
            2 * arch.n_kv_heads * arch.d_head * b for s in specs
            if s.mixer in ("attn", "attn_local"))
        logits = shape.global_batch * arch.vocab_size * 4 / n_dev
        return w + tokens_dev * act + cache_write + logits

    # decode: one token per sequence against a seq_len cache
    touch = _moe_touch_frac(arch, shape.global_batch)
    w = _weights_bytes(arch, flags, tp, touch_frac=touch)
    cache = 0.0
    for s in specs:
        if s.mixer in ("attn", "attn_local"):
            span = (min(arch.sliding_window, shape.seq_len)
                    if s.mixer == "attn_local" else shape.seq_len)
            cache += shape.global_batch * span * 2 * arch.n_kv_heads * arch.d_head * b
        else:
            cache += (shape.global_batch * arch.n_ssm_heads * arch.ssm_head_dim
                      * arch.ssm_state * 4)
    act = shape.global_batch * sum(
        _layer_token_bytes(arch, s, flags, 1) for s in specs)
    logits = shape.global_batch * arch.vocab_size * 4
    return w + (cache + act + logits) / n_dev
