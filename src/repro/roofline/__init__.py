from repro.roofline.hw import HwModel, PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK
from repro.roofline.analysis import Artifact, summarize, roofline_report, collective_wire_bytes
