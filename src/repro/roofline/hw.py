"""TPU v5e hardware model: roofline constants, DVFS-style ladders, power.

The Jetson-knob analogy (DESIGN.md §2):
  clock_scale — GPU-frequency ladder (11 steps, like Orin's 306 MHz–1.3 GHz)
  hbm_scale   — EMC-frequency ladder (4 steps; the lowest step mirrors Orin's
                204 MHz/3.2 GHz ≈ 1/16 ratio, which produces the paper's
                detached low-EMC cluster)
  ici_scale   — interconnect ladder (no Jetson analogue; TPU-specific)

Power model (documented, *modeled* constants — this container cannot measure):
  P_chip = IDLE_W
         + COMPUTE_W * clock_scale^2.5 * compute_utilisation
         + HBM_W     * hbm_scale       * memory_utilisation
The 2.5 exponent approximates dynamic power ∝ f·V² with V roughly ∝ √f.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

# -- TPU v5e per-chip peaks (assignment-specified constants) -----------------
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
PEAK_FLOPS_FP32 = PEAK_FLOPS_BF16 / 4
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s per link (formula uses chips × link_bw)

# -- modeled power envelope ---------------------------------------------------
IDLE_W = 75.0
COMPUTE_W = 110.0
HBM_W = 30.0

CLOCK_LADDER = tuple(round(0.5 + 0.05 * i, 2) for i in range(11))  # 0.5 … 1.0
HBM_LADDER = (1.0 / 16.0, 1.0 / 3.0, 2.0 / 3.0, 1.0)               # EMC analogue
ICI_LADDER = (0.25, 0.5, 0.75, 1.0)


@dataclasses.dataclass(frozen=True)
class HwModel:
    n_chips: int
    clock_scale: float = 1.0
    hbm_scale: float = 1.0
    ici_scale: float = 1.0
    dtype: str = "bfloat16"

    @property
    def peak_flops(self) -> float:
        base = PEAK_FLOPS_FP32 if self.dtype == "float32" else PEAK_FLOPS_BF16
        return base * self.clock_scale

    @property
    def hbm_bw(self) -> float:
        return HBM_BW * self.hbm_scale

    @property
    def ici_bw(self) -> float:
        return ICI_BW_PER_LINK * self.ici_scale

    # -- roofline terms (global quantities in, seconds out) -------------------
    def roofline_terms(self, flops: float, hbm_bytes: float,
                       collective_bytes: float) -> dict:
        t_comp = flops / (self.n_chips * self.peak_flops)
        t_mem = hbm_bytes / (self.n_chips * self.hbm_bw)
        t_coll = collective_bytes / (self.n_chips * self.ici_bw)
        terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
        terms["dominant"] = max(terms, key=lambda k: terms[k])
        # optimistic overlapped execution: bound by the slowest resource
        terms["step_time_s"] = max(t_comp, t_mem, t_coll)
        return terms

    def roofline_terms_batch(self, flops, hbm_bytes, collective_bytes) -> Dict[str, np.ndarray]:
        """Vectorized :meth:`roofline_terms` over ``(N,)`` arrays of traffic."""
        return _roofline_terms_vec(self.n_chips, self.peak_flops, self.hbm_bw,
                                   self.ici_bw, flops, hbm_bytes,
                                   collective_bytes)

    # -- power ---------------------------------------------------------------
    def power_w(self, flops: float, hbm_bytes: float, step_time_s: float) -> float:
        """Average per-chip power over one step."""
        if step_time_s <= 0:
            return IDLE_W
        util_c = flops / (self.n_chips * self.peak_flops) / step_time_s
        util_m = hbm_bytes / (self.n_chips * self.hbm_bw) / step_time_s
        util_c, util_m = min(util_c, 1.0), min(util_m, 1.0)
        return (IDLE_W
                + COMPUTE_W * (self.clock_scale ** 2.5) * util_c
                + HBM_W * self.hbm_scale * util_m)


def _clock_pow_2_5(clock_scale: np.ndarray) -> np.ndarray:
    """``clock_scale ** 2.5`` elementwise, via *Python* pow on unique values.

    ``np.power`` and CPython's float pow round the last ulp differently; the
    batched path must be bit-identical to the scalar path, and the clock
    ladder has ≤ 11 distinct values, so mapping through Python pow is both
    exact and cheap.
    """
    uniq, inv = np.unique(clock_scale, return_inverse=True)
    return np.asarray([float(c) ** 2.5 for c in uniq], np.float64)[inv]


def _roofline_terms_vec(n_chips, peak_flops, hbm_bw, ici_bw,
                        flops, hbm_bytes, collective_bytes) -> Dict[str, np.ndarray]:
    """Shared vectorized roofline core; every input broadcasts to ``(N,)``.

    Mirrors ``HwModel.roofline_terms`` operation-for-operation so results are
    bit-identical to the scalar sweep (IEEE basic ops are exactly rounded, so
    elementwise numpy float64 == Python float arithmetic).
    """
    t_comp = np.asarray(flops, np.float64) / (n_chips * peak_flops)
    t_mem = np.asarray(hbm_bytes, np.float64) / (n_chips * hbm_bw)
    t_coll = np.asarray(collective_bytes, np.float64) / (n_chips * ici_bw)
    t_comp, t_mem, t_coll = np.broadcast_arrays(t_comp, t_mem, t_coll)
    stacked = np.stack([t_comp, t_mem, t_coll])
    # argmax ties resolve to the first index — same order as the scalar dict
    names = np.asarray(["compute_s", "memory_s", "collective_s"])
    return {
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": names[np.argmax(stacked, axis=0)],
        "step_time_s": np.max(stacked, axis=0),
    }


class HwModelBatch:
    """Vectorized view over N hw-knob variants sharing ``n_chips``/``dtype``.

    This is the measurement half of the batched fast path: one compiled
    artifact (fixed sw knobs → fixed flops/bytes/wire-bytes) swept across the
    hardware ladders as ``(N,)`` numpy arrays instead of N scalar
    ``HwModel`` round-trips.  All outputs are bit-identical to the scalar
    :class:`HwModel` methods (see ``_clock_pow_2_5`` for the one libm
    subtlety).
    """

    def __init__(self, n_chips: int, clock_scale: np.ndarray,
                 hbm_scale: np.ndarray, ici_scale: np.ndarray,
                 dtype: str = "bfloat16"):
        self.n_chips = n_chips
        self.clock_scale = np.asarray(clock_scale, np.float64)
        self.hbm_scale = np.asarray(hbm_scale, np.float64)
        self.ici_scale = np.asarray(ici_scale, np.float64)
        self.dtype = dtype
        assert self.clock_scale.shape == self.hbm_scale.shape == self.ici_scale.shape
        self._cpow: Optional[np.ndarray] = None
        # JTime and JPower both sweep the same (prefill, decode) artifacts
        # over this batch; memoising by the scalar traffic triple halves the
        # numpy work without changing any returned value
        self._terms_memo: Dict[Tuple[float, float, float],
                               Dict[str, np.ndarray]] = {}

    @classmethod
    def from_models(cls, models: Sequence[HwModel]) -> "HwModelBatch":
        assert models, "empty batch"
        n_chips, dtype = models[0].n_chips, models[0].dtype
        assert all(m.n_chips == n_chips and m.dtype == dtype for m in models), \
            "a batch shares n_chips and dtype (both are sw-fingerprint fields)"
        return cls(n_chips,
                   np.asarray([m.clock_scale for m in models], np.float64),
                   np.asarray([m.hbm_scale for m in models], np.float64),
                   np.asarray([m.ici_scale for m in models], np.float64),
                   dtype)

    def __len__(self) -> int:
        return self.clock_scale.shape[0]

    def iter_models(self):
        """Scalar ``HwModel`` per variant — the un-vectorized fallback view."""
        for c, h, i in zip(self.clock_scale, self.hbm_scale, self.ici_scale):
            yield HwModel(n_chips=self.n_chips, clock_scale=float(c),
                          hbm_scale=float(h), ici_scale=float(i),
                          dtype=self.dtype)

    @property
    def peak_flops(self) -> np.ndarray:
        base = PEAK_FLOPS_FP32 if self.dtype == "float32" else PEAK_FLOPS_BF16
        return base * self.clock_scale

    @property
    def hbm_bw(self) -> np.ndarray:
        return HBM_BW * self.hbm_scale

    @property
    def ici_bw(self) -> np.ndarray:
        return ICI_BW_PER_LINK * self.ici_scale

    def roofline_terms_batch(self, flops, hbm_bytes, collective_bytes) -> Dict[str, np.ndarray]:
        """Per-variant roofline terms; traffic args are scalars or ``(N,)``."""
        key = None
        if (isinstance(flops, float) and isinstance(hbm_bytes, float)
                and isinstance(collective_bytes, float)):
            key = (flops, hbm_bytes, collective_bytes)
            hit = self._terms_memo.get(key)
            if hit is not None:
                return hit
        terms = _roofline_terms_vec(self.n_chips, self.peak_flops, self.hbm_bw,
                                    self.ici_bw, flops, hbm_bytes,
                                    collective_bytes)
        if key is not None:
            self._terms_memo[key] = terms
        return terms

    def power_w_batch(self, flops, hbm_bytes, step_time_s) -> np.ndarray:
        """Vectorized ``HwModel.power_w`` over ``(N,)`` step times."""
        if self._cpow is None:
            self._cpow = _clock_pow_2_5(self.clock_scale)
        t = np.asarray(step_time_s, np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            util_c = np.asarray(flops, np.float64) / (self.n_chips * self.peak_flops) / t
            util_m = np.asarray(hbm_bytes, np.float64) / (self.n_chips * self.hbm_bw) / t
        util_c = np.minimum(util_c, 1.0)
        util_m = np.minimum(util_m, 1.0)
        p = (IDLE_W
             + COMPUTE_W * self._cpow * util_c
             + HBM_W * self.hbm_scale * util_m)
        return np.where(t <= 0, IDLE_W, p)
