"""TPU v5e hardware model: roofline constants, DVFS-style ladders, power.

The Jetson-knob analogy (DESIGN.md §2):
  clock_scale — GPU-frequency ladder (11 steps, like Orin's 306 MHz–1.3 GHz)
  hbm_scale   — EMC-frequency ladder (4 steps; the lowest step mirrors Orin's
                204 MHz/3.2 GHz ≈ 1/16 ratio, which produces the paper's
                detached low-EMC cluster)
  ici_scale   — interconnect ladder (no Jetson analogue; TPU-specific)

Power model (documented, *modeled* constants — this container cannot measure):
  P_chip = IDLE_W
         + COMPUTE_W * clock_scale^2.5 * compute_utilisation
         + HBM_W     * hbm_scale       * memory_utilisation
The 2.5 exponent approximates dynamic power ∝ f·V² with V roughly ∝ √f.
"""
from __future__ import annotations

import dataclasses

# -- TPU v5e per-chip peaks (assignment-specified constants) -----------------
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
PEAK_FLOPS_FP32 = PEAK_FLOPS_BF16 / 4
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s per link (formula uses chips × link_bw)

# -- modeled power envelope ---------------------------------------------------
IDLE_W = 75.0
COMPUTE_W = 110.0
HBM_W = 30.0

CLOCK_LADDER = tuple(round(0.5 + 0.05 * i, 2) for i in range(11))  # 0.5 … 1.0
HBM_LADDER = (1.0 / 16.0, 1.0 / 3.0, 2.0 / 3.0, 1.0)               # EMC analogue
ICI_LADDER = (0.25, 0.5, 0.75, 1.0)


@dataclasses.dataclass(frozen=True)
class HwModel:
    n_chips: int
    clock_scale: float = 1.0
    hbm_scale: float = 1.0
    ici_scale: float = 1.0
    dtype: str = "bfloat16"

    @property
    def peak_flops(self) -> float:
        base = PEAK_FLOPS_FP32 if self.dtype == "float32" else PEAK_FLOPS_BF16
        return base * self.clock_scale

    @property
    def hbm_bw(self) -> float:
        return HBM_BW * self.hbm_scale

    @property
    def ici_bw(self) -> float:
        return ICI_BW_PER_LINK * self.ici_scale

    # -- roofline terms (global quantities in, seconds out) -------------------
    def roofline_terms(self, flops: float, hbm_bytes: float,
                       collective_bytes: float) -> dict:
        t_comp = flops / (self.n_chips * self.peak_flops)
        t_mem = hbm_bytes / (self.n_chips * self.hbm_bw)
        t_coll = collective_bytes / (self.n_chips * self.ici_bw)
        terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
        terms["dominant"] = max(terms, key=lambda k: terms[k])
        # optimistic overlapped execution: bound by the slowest resource
        terms["step_time_s"] = max(t_comp, t_mem, t_coll)
        return terms

    # -- power ---------------------------------------------------------------
    def power_w(self, flops: float, hbm_bytes: float, step_time_s: float) -> float:
        """Average per-chip power over one step."""
        if step_time_s <= 0:
            return IDLE_W
        util_c = flops / (self.n_chips * self.peak_flops) / step_time_s
        util_m = hbm_bytes / (self.n_chips * self.hbm_bw) / step_time_s
        util_c, util_m = min(util_c, 1.0), min(util_m, 1.0)
        return (IDLE_W
                + COMPUTE_W * (self.clock_scale ** 2.5) * util_c
                + HBM_W * self.hbm_scale * util_m)
