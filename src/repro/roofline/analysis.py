"""Roofline extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` reports **per-device** FLOPs / bytes accessed
(verified empirically: a 4-way-sharded 1024³ matmul reports 2·1024³/4 FLOPs).
Collective traffic is NOT in cost_analysis, so we parse the optimized HLO of
``compiled.as_text()`` and sum wire bytes of every collective op using the
standard ring-algorithm costs:

  all-gather        out_bytes · (g-1)/g         (out = gathered result)
  all-reduce        2 · bytes · (g-1)/g         (reduce-scatter + all-gather)
  reduce-scatter    out_bytes · (g-1)            (out = scattered shard)
  all-to-all        bytes · (g-1)/g
  collective-permute bytes                       (single hop)

where g is the replica-group size.  These are per-device wire bytes; the
roofline collective term is wire_bytes_per_device / ici_bw, which equals the
assignment's ``collective_bytes / (chips × link_bw)`` with global bytes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast|ragged-all-to-all)"
    r"(-start)?\(",
)
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def collective_wire_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, from optimized HLO text."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind, _ = m.groups()
        size = _shape_bytes(type_str)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind in ("all-reduce", "collective-broadcast"):
            wire = 2 * size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)
        elif kind in ("all-to-all", "ragged-all-to-all"):
            wire = size * (g - 1) / g
        else:  # collective-permute
            wire = size
        out[kind] = out.get(kind, 0.0) + wire
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class Artifact:
    """Everything JMeasure needs, extracted once per compile."""
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    collectives: Dict[str, float]
    arg_bytes: int
    temp_bytes: int
    output_bytes: int
    n_devices: int
    hlo_ops: Optional[Dict[str, int]] = None
    # analytic fusion-aware HBM traffic (roofline/traffic.py); the raw
    # 'bytes accessed' above overstates TPU HBM traffic (no fusion modeling)
    hbm_est_per_device: Optional[float] = None

    @property
    def global_flops(self) -> float:
        return self.flops_per_device * self.n_devices

    @property
    def effective_bytes_per_device(self) -> float:
        return (self.hbm_est_per_device if self.hbm_est_per_device is not None
                else self.bytes_per_device)

    @property
    def peak_memory_per_device(self) -> int:
        return self.arg_bytes + self.temp_bytes + self.output_bytes


def summarize(compiled, n_devices: int, with_ops: bool = False) -> Artifact:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax < 0.5 returns [per-device dict]
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_wire_bytes(txt, n_devices)
    ops = None
    if with_ops:
        ops = {}
        for m in re.finditer(r"=\s*\S+\s+([a-z][a-z0-9-]*)\(", txt):
            ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    return Artifact(
        flops_per_device=float(ca.get("flops", 0.0)),
        bytes_per_device=float(ca.get("bytes accessed", 0.0)),
        wire_bytes_per_device=coll.get("total", 0.0),
        collectives={k: v for k, v in coll.items() if k != "total"},
        arg_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        n_devices=n_devices,
        hlo_ops=ops,
    )


def roofline_report(art: Artifact, hw) -> dict:
    """Three-term roofline + dominant bottleneck for one artifact."""
    terms = hw.roofline_terms(art.global_flops,
                              art.bytes_per_device * art.n_devices,
                              art.wire_bytes_per_device * art.n_devices)
    terms.update(
        flops_per_device=art.flops_per_device,
        bytes_per_device=art.bytes_per_device,
        wire_bytes_per_device=art.wire_bytes_per_device,
        peak_mem_per_device=art.peak_memory_per_device,
    )
    return terms
