"""End-to-end training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train \
        --arch tinyllama-1.1b --reduced --steps 50 --batch 8 --seq 128 \
        --checkpoint-dir /tmp/ckpt --save-every 10

Restart semantics: on startup the latest checkpoint in --checkpoint-dir is
restored and the data pipeline is fast-forwarded to the restored step, so a
killed run resumes bit-exactly (the data pipeline is a pure function of
(seed, step)).  ``--fault-at N`` injects a crash at step N to demonstrate.
"""
from __future__ import annotations

import argparse
import time


def parse_args():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b")
    p.add_argument("--reduced", action="store_true",
                   help="reduced same-family config (CPU-sized)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    p.add_argument("--microbatch", type=int, default=1)
    p.add_argument("--remat", default="selective")
    p.add_argument("--dtype", default="float32")
    p.add_argument("--grad-compress", action="store_true")
    p.add_argument("--checkpoint-dir", default="")
    p.add_argument("--save-every", type=int, default=20)
    p.add_argument("--keep", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--fault-at", type=int, default=-1,
                   help="inject a crash at this step (fault-tolerance demo)")
    p.add_argument("--log-every", type=int, default=10)
    return p.parse_args()


def main():
    args = parse_args()
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, reduced
    from repro.data import DataConfig, SyntheticLM, device_put_batch
    from repro.launch.mesh import make_host_mesh
    from repro.models import BuildFlags, Model
    from repro.parallel.sharding import ShardingPolicy
    from repro.train import (CheckpointManager, TrainStepConfig, adafactor,
                             adamw, cosine_schedule, init_train_state,
                             make_train_step)

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)
    mesh = make_host_mesh()
    policy = ShardingPolicy(mesh, sp=False) if mesh.size > 1 else None
    flags = BuildFlags(dtype=args.dtype, remat=args.remat, sp=False)
    model = Model(arch, flags, policy)
    sched = cosine_schedule(args.lr, max(args.steps // 20, 1), args.steps)
    opt = adafactor(sched) if args.optimizer == "adafactor" else adamw(sched)
    tsc = TrainStepConfig(microbatch=args.microbatch,
                          grad_compress=args.grad_compress)
    step_fn = jax.jit(make_train_step(model, opt, tsc), donate_argnums=(0,))

    state = init_train_state(model, opt, jax.random.key(args.seed), tsc)
    start = 0
    ck = None
    if args.checkpoint_dir:
        ck = CheckpointManager(args.checkpoint_dir, keep=args.keep)
        latest = ck.latest_step()
        if latest is not None:
            state = ck.restore(latest, jax.eval_shape(lambda: state))
            start = latest
            print(f"[train] resumed from step {start}")

    data = SyntheticLM(arch, DataConfig(args.batch, args.seq, args.seed))
    t0 = time.time()
    for step in range(start, args.steps):
        if step == args.fault_at:
            if ck:
                # crash at a step boundary with in-flight checkpoint IO
                # drained — mid-write crashes are separately survivable via
                # the tmp+rename atomic publish (restore ignores .tmp dirs)
                ck.wait()
            print(f"[train] injected fault at step {step}", flush=True)
            raise SystemExit(42)
        batch = device_put_batch(data.batch(step), policy)
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
            loss = float(metrics["loss"])
            print(f"[train] step {step+1:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(step-start+1)*1e3:.0f} ms/step)", flush=True)
        if ck and (step + 1) % args.save_every == 0:
            ck.save(step + 1, state)
    if ck:
        ck.save(args.steps, state, block=True)
        ck.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
