"""End-to-end serving driver: batched greedy generation.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch tinyllama-1.1b --reduced --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time


def parse_args():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="tinyllama-1.1b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--dtype", default="float32")
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args()


def main():
    args = parse_args()
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch, reduced
    from repro.models import BuildFlags, Model
    from repro.serve import Engine

    arch = get_arch(args.arch)
    if args.reduced:
        arch = reduced(arch)
    model = Model(arch, BuildFlags(dtype=args.dtype, remat="none", sp=False))
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    batch = {}
    ptoks = args.prompt_len
    if arch.frontend == "vision":
        f = arch.n_frontend_tokens
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, f, arch.d_model), dtype=np.float32))
        ptoks = max(args.prompt_len - f, 1)
    if arch.frontend == "audio":
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, ptoks, arch.d_model), dtype=np.float32))
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, arch.vocab_size, (args.batch, ptoks)), jnp.int32)

    eng = Engine(model, params, max_len=args.prompt_len + args.gen + 1)
    t0 = time.time()
    res = eng.generate(batch, args.gen)
    dt = time.time() - t0
    print(f"[serve] arch={arch.name} batch={args.batch} prompt={res.n_prompt} "
          f"generated={res.n_generated} in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("[serve] first sequence:", res.tokens[0][:16].tolist())


if __name__ == "__main__":
    main()
