"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun.jsonl, plus end-of-run reporting helpers shared by
``launch.explore`` and the benchmark harness (``cache_effectiveness``).

    PYTHONPATH=src python -m repro.launch.report [--in results/dryrun.jsonl]
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict


def cache_effectiveness(cache_infos, fleet_stats=None):
    """Fold per-client ``JClient.cache_info()`` dicts (+ optional
    ``FleetArtifactStore.stats()``) into one human summary line and a flat
    totals dict (the ``results/bench.json`` fleet-row payload).

    Tier semantics: ``hits``/``misses`` are the in-memory LRU, ``disk_*``
    the persistent tier, ``fleet_*`` the host-mediated store; byte counters
    are summed across clients.
    """
    totals = defaultdict(int)
    for ci in cache_infos or ():
        for k, v in (ci or {}).items():
            if k == "maxsize":
                continue
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                totals[k] += v
    out = dict(totals)
    out["n_clients"] = len(cache_infos or ())
    parts = [f"lru {out.get('hits', 0)}/{out.get('hits', 0) + out.get('misses', 0)} hits"]
    if "disk_hits" in out:
        parts.append(f"disk {out['disk_hits']}/"
                     f"{out['disk_hits'] + out.get('disk_misses', 0)} hits")
    if "fleet_hits" in out:
        mb_in = out.get("fleet_bytes_in", 0) / 1e6
        parts.append(f"fleet {out['fleet_hits']}/"
                     f"{out['fleet_hits'] + out.get('fleet_misses', 0)} hits "
                     f"({mb_in:.2f} MB fetched)")
    if fleet_stats:
        for k, v in fleet_stats.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[f"store_{k.replace('fleet_', '')}"] = v
        parts.append(f"store {fleet_stats.get('fleet_mode', '?')}: "
                     f"{fleet_stats.get('fleet_hits', 0)} served, "
                     f"{fleet_stats.get('fleet_misses', 0)} compiles assigned, "
                     f"{fleet_stats.get('fleet_served_mb', 0.0):.2f} MB out")
    return "cache: " + ", ".join(parts), out


def load(path, variant="baseline"):
    cells = {}
    for line in open(path):
        try:
            r = json.loads(line)
        except Exception:
            continue
        if r.get("variant", "baseline") != variant:
            continue
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_t(s):
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.1f}ms"
    return f"{s*1e6:.0f}µs"


def fmt_b(b):
    if b >= 2 ** 30:
        return f"{b/2**30:.1f}GiB"
    return f"{b/2**20:.0f}MiB"


DOM = {"compute_s": "compute", "memory_s": "memory", "collective_s": "collective"}


def roofline_table(cells, mesh="16x16"):
    rows = ["| arch | shape | compute | memory (est) | collective | bottleneck "
            "| step | RF | 6ND/HLO | peak mem/dev | fits 16G |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {arch} | {shape} | — | — | — | skipped | — | — | — "
                        f"| — | ({r['reason'].split(':')[0]}) |")
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            rows.append(f"| {arch} | {shape} | FAILED | | | | | | | | |")
            continue
        ro = r["roofline"]
        mem = r["memory"]
        rows.append(
            f"| {arch} | {shape} | {fmt_t(ro['compute_s'])} "
            f"| {fmt_t(ro['memory_s'])} | {fmt_t(ro['collective_s'])} "
            f"| {DOM.get(ro['dominant'], ro['dominant'])} "
            f"| {fmt_t(ro['step_time_s'])} | {ro['roofline_fraction']:.2f} "
            f"| {ro['useful_ratio']:.2f} | {fmt_b(mem['peak_per_device'])} "
            f"| {'yes' if mem['fits_16g_hbm'] else 'NO'} |")
    return "\n".join(rows)


def dryrun_table(cells):
    """Compile-proof summary: one row per (arch, shape), both meshes."""
    byas = defaultdict(dict)
    for (arch, shape, m), r in cells.items():
        byas[(arch, shape)][m] = r
    rows = ["| arch | shape | 16×16 | 2×16×16 | compile s (single/multi) "
            "| bytes/dev | top collectives (single) |",
            "|---|---|---|---|---|---|---|"]
    for (arch, shape), by in sorted(byas.items()):
        marks, comps = [], []
        for m in ("16x16", "2x16x16"):
            r = by.get(m)
            if r is None:
                marks.append("—")
                comps.append("—")
            elif r.get("status") == "ok":
                marks.append("✓")
                comps.append(f"{r.get('compile_s', 0):.0f}")
            elif r.get("status") == "skipped":
                marks.append("skip")
                comps.append("—")
            else:
                marks.append("FAIL")
                comps.append("—")
        r = by.get("16x16", {})
        mem = r.get("memory", {})
        coll = (r.get("cost", {}) or {}).get("collectives", {})
        top = ", ".join(f"{k}:{fmt_b(v)}" for k, v in
                        sorted(coll.items(), key=lambda kv: -kv[1])[:2])
        rows.append(f"| {arch} | {shape} | {marks[0]} | {marks[1]} "
                    f"| {comps[0]}/{comps[1]} "
                    f"| {fmt_b(mem.get('peak_per_device', 0))} | {top} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--section", default="all", choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    cells = load(args.inp, args.variant)
    if args.section in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table(cells))
        print()
    if args.section in ("all", "roofline"):
        print("### Roofline (single pod, 16×16 = 256 chips)\n")
        print(roofline_table(cells))


if __name__ == "__main__":
    main()
