"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax


def _make(shape, axes):
    # jax < 0.5 has neither sharding.AxisType nor the axis_types kwarg; every
    # axis defaults to Auto there, which is exactly what we request on >= 0.5
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; ×2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh_dp_tp(dp: int, tp: int, pods: int = 1):
    """Explicit factorisation (the dp_degree design-space knob)."""
    if pods > 1:
        return _make((pods, dp, tp), ("pod", "data", "model"))
    return _make((dp, tp), ("data", "model"))


def make_host_mesh():
    """Whatever devices this process actually has — smoke tests/examples."""
    n = len(jax.devices())
    return _make((n,), ("data",)) if n > 1 else _make((1,), ("data",))
