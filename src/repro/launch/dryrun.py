import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun``.
The XLA_FLAGS line above executes before any jax import (jax locks the device
count on first init); this module must therefore be imported before jax in
this process.

Per cell:
  * full-depth ``.lower().compile()`` on the production mesh — the
    compile-feasibility proof; ``memory_analysis()`` proves (or disproves)
    HBM fit;
  * two shallow UNROLLED builds (1× and 2× the layer pattern) whose exact
    cost delta gives per-group FLOPs/bytes/collective-wire-bytes; totals are
    extrapolated c1 + (G-1)·(c2-c1) because XLA cost analysis counts a
    lax.scan (while-loop) body once regardless of trip count (verified).
    G = n_layers / len(pattern), fractional for remainder layers (gemma3's
    62 = 10·6+2 — documented approximation).

Results stream to a JSONL (resumable: existing cells are skipped).
"""
import argparse
import dataclasses
import json
import time
import traceback


def parse_args():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="all", help="arch id, csv, or 'all'")
    p.add_argument("--shape", default="all", help="shape name, csv, or 'all'")
    p.add_argument("--mesh", default="single,multi")
    p.add_argument("--out", default="results/dryrun.jsonl")
    p.add_argument("--force", action="store_true")
    p.add_argument("--flags", default="", help="k=v csv of BuildFlags overrides")
    p.add_argument("--variant", default="baseline", help="label for §Perf runs")
    p.add_argument("--skip-costs", action="store_true",
                   help="full compile only (no shallow cost builds)")
    return p.parse_args()


def build_flags_from(s: str):
    from repro.models.model import BuildFlags

    kw = {}
    if s:
        for kv in s.split(","):
            k, v = kv.split("=")
            field = {f.name: f for f in dataclasses.fields(BuildFlags)}[k]
            if field.type in ("bool", bool):
                kw[k] = v.lower() in ("1", "true", "yes")
            elif field.type in ("int", int):
                kw[k] = int(v)
            else:
                kw[k] = v
    return BuildFlags(**kw)


def shallow_arch(arch, k: int):
    """Depth = k pattern groups (keeps first_k_dense deviance for k≥1)."""
    return dataclasses.replace(arch, n_layers=k * len(arch.pattern),
                               name=f"{arch.name}@depth{k}")


def measure_cell(arch, shape, mesh, flags, skip_costs=False):
    """Returns the dry-run record for one cell."""
    import jax

    from repro.launch.build import build_cell
    from repro.roofline.analysis import summarize, Artifact

    n_dev = mesh.size
    t0 = time.time()
    full = build_cell(arch, shape, mesh, flags)
    t_compile = time.time() - t0
    full_art = summarize(full.compiled, n_dev)

    rec = {
        "arch": arch.name, "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": n_dev,
        "kind": full.kind,
        "flags": dataclasses.asdict(flags),
        "compile_s": round(t_compile, 2),
        "memory": {
            "arg_bytes": full_art.arg_bytes,
            "temp_bytes": full_art.temp_bytes,
            "output_bytes": full_art.output_bytes,
            "peak_per_device": full_art.peak_memory_per_device,
            "fits_16g_hbm": full_art.peak_memory_per_device <= 16 * 2 ** 30,
        },
        "meta": full.meta,
    }
    if skip_costs:
        return rec

    # per-layer-type cost extraction: for each distinct LayerSpec, build
    # 1-layer and 2-layer UNROLLED models; their delta is that layer type's
    # exact post-optimization cost, and the 1-layer build minus its own delta
    # is the embed/head/loss base.  total = base + Σ_layers delta(spec).
    # (Cheaper and more exact than depth-1/depth-2 pattern-group builds for
    # heterogeneous patterns like jamba's 8-layer group.)
    sflags = dataclasses.replace(flags, unroll=True)
    specs = arch.layer_specs()
    per_spec = {}
    for sp in dict.fromkeys(specs):  # distinct, order-preserving
        a1 = dataclasses.replace(arch, n_layers=1, pattern=(sp,),
                                 first_k_dense=0,
                                 name=f"{arch.name}@{sp.mixer}-{sp.ffn}x1")
        a2 = dataclasses.replace(arch, n_layers=2, pattern=(sp,),
                                 first_k_dense=0,
                                 name=f"{arch.name}@{sp.mixer}-{sp.ffn}x2")
        per_spec[sp] = (
            summarize(build_cell(a1, shape, mesh, sflags).compiled, n_dev),
            summarize(build_cell(a2, shape, mesh, sflags).compiled, n_dev))

    def get(a, q):
        if q.startswith("coll:"):
            return a.collectives.get(q[5:], 0.0)
        return getattr(a, q)

    def total(q, full_v=0.0):
        s0 = specs[0]
        c1, c2 = per_spec[s0]
        d0 = max(get(c2, q) - get(c1, q), 0.0)
        base = max(get(c1, q) - d0, 0.0)
        tot = base
        for sp in specs:
            c1s, c2s = per_spec[sp]
            tot += max(get(c2s, q) - get(c1s, q), 0.0)
        return max(tot, full_v)

    kinds = set(full_art.collectives)
    for c1s, c2s in per_spec.values():
        kinds |= set(c1s.collectives) | set(c2s.collectives)
    coll = {kk: total(f"coll:{kk}", full_art.collectives.get(kk, 0.0))
            for kk in kinds}
    art = Artifact(
        flops_per_device=total("flops_per_device", full_art.flops_per_device),
        bytes_per_device=total("bytes_per_device", full_art.bytes_per_device),
        wire_bytes_per_device=sum(coll.values()),
        collectives=coll,
        arg_bytes=full_art.arg_bytes,
        temp_bytes=full_art.temp_bytes,
        output_bytes=full_art.output_bytes,
        n_devices=n_dev,
    )
    rec["cost"] = {
        "flops_per_device": art.flops_per_device,
        "bytes_per_device": art.bytes_per_device,
        "wire_bytes_per_device": art.wire_bytes_per_device,
        "collectives": coll,
        "method": "per-layer-type delta",
    }
    return rec, art


def roofline_record(rec, art, arch, shape, flags, mesh):
    from repro.roofline.hw import HwModel
    from repro.roofline.traffic import analytic_hbm_bytes_per_device
    from repro.models.model import count_params_analytic
    from repro.launch.build import pick_optimizer

    hw = HwModel(n_chips=art.n_devices)
    # analytic fusion-aware HBM estimate (the CPU backend's 'bytes accessed'
    # has no TPU fusion model and overstates traffic ~5-10×; both reported)
    tp = mesh.shape.get("model", 1)
    dp = art.n_devices // tp
    _, opt_name = pick_optimizer(arch) if shape.kind == "train" else (None, "none")
    art.hbm_est_per_device = analytic_hbm_bytes_per_device(
        arch, shape, flags, art.n_devices, dp, tp, optimizer=opt_name)
    terms = hw.roofline_terms(art.global_flops,
                              art.effective_bytes_per_device * art.n_devices,
                              art.wire_bytes_per_device * art.n_devices)
    terms_hlo = hw.roofline_terms(art.global_flops,
                                  art.bytes_per_device * art.n_devices,
                                  art.wire_bytes_per_device * art.n_devices)
    n_active = count_params_analytic(arch, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens
    rec["roofline"] = {
        **{k: v for k, v in terms.items()},
        "memory_s_hlo_raw": terms_hlo["memory_s"],
        "hbm_est_per_device": art.hbm_est_per_device,
        "model_flops": model_flops,
        "hlo_flops_global": art.global_flops,
        "useful_ratio": model_flops / art.global_flops if art.global_flops else 0.0,
        "roofline_fraction": (terms["compute_s"] / terms["step_time_s"]
                              if terms["step_time_s"] else 0.0),
    }
    return rec


def main():
    args = parse_args()
    import jax  # noqa: E402  (after XLA_FLAGS)

    from repro.configs import ASSIGNED_ARCHS, SHAPES, get_arch, shape_applicable
    from repro.launch.mesh import make_production_mesh

    archs = ASSIGNED_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    flags = build_flags_from(args.flags)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("variant")))
                except Exception:
                    pass

    from repro.launch.mesh import make_mesh_dp_tp

    mesh_objs = {}
    for m in meshes:
        if m in ("single", "multi"):
            mesh_objs[m] = make_production_mesh(multi_pod=(m == "multi"))
        else:  # "DPxTP" — §Perf mesh-factorisation variants (dp_degree knob)
            dp, tp = (int(x) for x in m.split("x"))
            mesh_objs[m] = make_mesh_dp_tp(dp, tp)

    n_ok = n_skip = n_fail = 0
    with open(args.out, "a") as out:
        for aname in archs:
            arch = get_arch(aname)
            for sname in shapes:
                shape = SHAPES[sname]
                runs, why = shape_applicable(arch, shape)
                for mname, mesh in mesh_objs.items():
                    mesh_tag = "x".join(str(s) for s in mesh.devices.shape)
                    key = (arch.name, shape.name, mesh_tag, args.variant)
                    if key in done:
                        n_skip += 1
                        continue
                    if not runs:
                        rec = {"arch": arch.name, "shape": shape.name,
                               "mesh": mesh_tag, "variant": args.variant,
                               "status": "skipped", "reason": why}
                        out.write(json.dumps(rec) + "\n")
                        out.flush()
                        print(f"[skip] {arch.name} × {shape.name} × {mesh_tag}: {why}")
                        continue
                    t0 = time.time()
                    try:
                        got = measure_cell(arch, shape, mesh, flags,
                                           skip_costs=args.skip_costs)
                        if args.skip_costs:
                            rec = got
                        else:
                            rec, art = got
                            rec = roofline_record(rec, art, arch, shape, flags, mesh)
                        rec["status"] = "ok"
                        rec["variant"] = args.variant
                        n_ok += 1
                        extra = ""
                        if "roofline" in rec:
                            r = rec["roofline"]
                            extra = (f" dom={r['dominant']}"
                                     f" step={r['step_time_s']*1e3:.1f}ms"
                                     f" rf={r['roofline_fraction']:.2f}")
                        print(f"[ok]   {arch.name} × {shape.name} × {mesh_tag} "
                              f"({time.time()-t0:.0f}s, "
                              f"peak={rec['memory']['peak_per_device']/2**30:.1f}GiB)"
                              + extra)
                    except Exception:
                        rec = {"arch": arch.name, "shape": shape.name,
                               "mesh": mesh_tag, "variant": args.variant,
                               "status": "failed",
                               "error": traceback.format_exc(limit=8)}
                        n_fail += 1
                        print(f"[FAIL] {arch.name} × {shape.name} × {mesh_tag} "
                              f"({time.time()-t0:.0f}s)")
                        print(rec["error"].splitlines()[-1])
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
    print(f"\ndone: ok={n_ok} skip={n_skip} fail={n_fail}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
