"""Cell builder: (arch × shape × flags × mesh) → lowered/compiled XLA.

This is the single entry point shared by the dry-run, the JClient workload
adapter and the benchmarks.  Nothing here allocates device memory — all
inputs are ShapeDtypeStructs; ``.lower().compile()`` produces the artifact
the roofline/measurement layers read.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, get_arch
from repro.models.model import BuildFlags, Model
from repro.parallel.sharding import ShardingPolicy
from repro.train.optimizer import adafactor, adamw, cosine_schedule
from repro.train.train_step import TrainStepConfig, make_train_step, train_state_shapes


def pick_optimizer(arch: ArchConfig, name: Optional[str] = None):
    """AdamW by default; Adafactor where AdamW state cannot fit a v5e pod
    (llama4-maverick-400b: 400e9 × 8 B fp32 slots > 4 TB pod HBM)."""
    if name is None:
        name = "adafactor" if arch.param_count() > 100e9 else "adamw"
    sched = cosine_schedule(3e-4, 2000, 100_000)
    return (adafactor(sched) if name == "adafactor" else adamw(sched)), name


@dataclasses.dataclass
class BuiltCell:
    kind: str
    lowered: Any
    compiled: Any
    n_devices: int
    meta: Dict[str, Any]


def _state_shardings(policy: ShardingPolicy, state_shapes):
    """Param-rule shardings for the whole train state (opt slots mirror the
    param paths, so the same path rules apply)."""
    return policy.param_shardings(state_shapes)


def build_cell(arch: ArchConfig, shape: ShapeConfig, mesh,
               flags: BuildFlags = BuildFlags(),
               tsc: TrainStepConfig = TrainStepConfig(),
               optimizer: Optional[str] = None,
               donate: bool = False,
               compile: bool = True) -> BuiltCell:
    policy = ShardingPolicy(mesh, sp=flags.sp, fsdp=flags.fsdp)
    model = Model(arch, flags, policy)
    n_dev = mesh.size
    meta: Dict[str, Any] = {"arch": arch.name, "shape": shape.name}

    if shape.kind == "train":
        opt, opt_name = pick_optimizer(arch, optimizer)
        meta["optimizer"] = opt_name
        step = make_train_step(model, opt, tsc, policy=policy)
        state_shapes = train_state_shapes(model, opt, tsc)
        state_sh = _state_shardings(policy, state_shapes)
        batch = model.input_specs(shape)
        batch_sh = policy.batch_shardings(batch)
        jfn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                      out_shardings=(state_sh, None),
                      donate_argnums=(0,) if donate else ())
        lowered = jfn.lower(state_shapes, batch)
    elif shape.kind == "prefill":
        batch = model.input_specs(shape)
        batch_sh = policy.batch_shardings(batch)
        params_shapes = model.init_shapes()
        params_sh = policy.param_shardings(params_shapes)
        jfn = jax.jit(model.prefill, in_shardings=(params_sh, batch_sh))
        lowered = jfn.lower(params_shapes, batch)
    elif shape.kind == "decode":
        params_shapes = model.init_shapes()
        params_sh = policy.param_shardings(params_shapes)
        cache_shapes = jax.eval_shape(
            lambda: model.empty_caches(shape.global_batch, shape.seq_len))
        cache_sh = policy.cache_shardings(cache_shapes)
        tokens = model.input_specs(shape)["tokens"]
        tok_sh = policy.sharding(policy.batch_spec(tokens.shape))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        jfn = jax.jit(model.decode_step,
                      in_shardings=(params_sh, tok_sh, cache_sh, policy.replicated()),
                      out_shardings=(None, cache_sh),
                      donate_argnums=(2,) if donate else ())
        lowered = jfn.lower(params_shapes, tokens, cache_shapes, pos)
    else:
        raise ValueError(shape.kind)

    compiled = lowered.compile() if compile else None
    return BuiltCell(shape.kind, lowered, compiled, n_dev, meta)


# ---------------------------------------------------------------------------
# Generation workload (the paper's Llama2/LLaVA experiments): prefill of a
# prompt + N greedy decode steps against a max_len cache.
# ---------------------------------------------------------------------------


def build_generation(arch: ArchConfig, mesh, flags: BuildFlags = BuildFlags(),
                     batch: int = 1, prompt_len: int = 64, max_len: int = 256,
                     ) -> Tuple[BuiltCell, BuiltCell]:
    pre = ShapeConfig("gen_prefill", "prefill", prompt_len, batch)
    dec = ShapeConfig("gen_decode", "decode", max_len, batch)
    return (build_cell(arch, pre, mesh, flags),
            build_cell(arch, dec, mesh, flags))
