import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""The JExplore driver: JHost + search algorithm + a real model workload.

Reproduces the paper's experiments on the TPU adaptation:

    PYTHONPATH=src python -m repro.launch.explore \
        --workload llama2-7b --samples 200 --algorithm random \
        --clients 2 --out results/llama2_explore.csv

Each "board" is a v5e-8 inference slice (tp=8); the workload is the paper's
generation task (prompt prefill + 150 greedy decode tokens).  Hardware-ladder
knobs (clock/HBM/ICI) re-evaluate the analytic JMeasure model against the
cached compiled artifact — exactly like re-clocking a Jetson without
redeploying the network; sw knobs recompile (JClient caches by fingerprint).

``--shape train_4k`` etc. switch the workload to a training/prefill/decode
step of the assigned architectures on a dp×tp slice of the same 8 devices.

GP surrogate modes and flags (bayesopt/pal only)
------------------------------------------------
``--gp incremental``  rank-append Cholesky per tell on the host CPU — O(n²)
  per update, cached across asks (the default, and the numerical reference).
``--gp refit``        full O(n³) refactor per ask (pre-incremental path,
  kept for benchmarking and equivalence tests).
``--gp jax``          device-resident fast path: the same incremental
  buffer layout lives on the accelerator as jitted, donated rank-appends;
  pool scoring (posterior means + EHVI staircase) is fused into one device
  call; past ``--gp-inducing`` observations a subset-of-data inducing-point
  approximation keeps the active set — and ask latency — flat into the
  10⁴+ regime.  Matches the numpy reference to float64 round-off while the
  active set is exact.
``--gp-inducing N``   inducing-point threshold for ``--gp jax``
  (default 5000; the active set is thinned to a stride of the archive once
  observations exceed ~1.25×N).
``--gp-refresh K``    hyperparameter refresh schedule, any mode: every K
  tells the RBF lengthscale is re-tuned (median-distance candidates scored
  by log marginal likelihood on a strided subsample) and the live factor is
  rebuilt in place.
``--speculate-slow-mult M``  queued-chunk speculation: chunks not yet
  started on a client whose per-config EWMA exceeds M× the median of the
  other healthy clients are mirrored elsewhere (first answer wins).
"""
import argparse
import threading
import time


def parse_args():
    p = argparse.ArgumentParser()
    p.add_argument("--workload", default="llama2-7b", help="arch id")
    p.add_argument("--shape", default="generate",
                   help="'generate' (paper workload) or a SHAPES name")
    p.add_argument("--samples", type=int, default=200)
    p.add_argument("--algorithm", default="random",
                   choices=["random", "grid", "nsga2", "bayesopt", "pal"])
    p.add_argument("--clients", type=int, default=2)
    p.add_argument("--chips", type=int, default=8, help="chips per board")
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen-tokens", type=int, default=150)
    p.add_argument("--out", default="results/explore.csv")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--timeout", type=float, default=600.0)
    p.add_argument("--batch-size", type=int, default=None,
                   help="configs per dispatched chunk (batched fast path); "
                        "default: scalar one-config-per-message dispatch")
    p.add_argument("--dispatch", default="eager",
                   choices=["eager", "pipelined"],
                   help="eager: a client gets its next chunk only after "
                        "answering its current one; pipelined: keep every "
                        "client's queue 2 chunks deep (double-buffering)")
    p.add_argument("--chunk-budget-ms", type=float, default=None,
                   help="adaptive chunk sizing: target this wall-time budget "
                        "per chunk from an EWMA of observed per-config wall "
                        "time per client (replaces the static --batch-size)")
    p.add_argument("--codec", default="json", choices=["json", "binary"],
                   help="wire codec: binary packs columnar frames' numeric "
                        "columns as typed arrays (fleet-friendly)")
    p.add_argument("--affinity", default="off",
                   choices=["off", "prefer", "strict"],
                   help="compile-affinity placement: route chunks to the "
                        "client already holding their sw fingerprint "
                        "compiled (prefer: steal rather than idle; strict: "
                        "a fingerprint's work always waits for its home "
                        "client while it is healthy)")
    p.add_argument("--pipeline-depth", type=int, default=None,
                   help="queued chunks per client under --dispatch "
                        "pipelined (default 2 = double-buffering; deeper "
                        "hides higher-latency links)")
    p.add_argument("--speculate-at", type=float, default=None, metavar="FRAC",
                   help="speculative re-dispatch: mirror a running chunk to "
                        "a second client once it has burned this fraction "
                        "of its deadline budget (first answer wins)")
    p.add_argument("--speculate-slow-mult", type=float, default=None,
                   metavar="MULT",
                   help="queued-chunk speculation: mirror chunks not yet "
                        "started on a client whose per-config EWMA exceeds "
                        "this multiple of the median of the other healthy "
                        "clients' EWMAs (first answer wins)")
    p.add_argument("--cache-dir", default=None,
                   help="persistent artifact cache root: compiled artifacts "
                        "are pickled content-addressed under "
                        "<cache-dir>/client<i>/ so restarted clients and "
                        "repeated sweeps skip the compile (layout + "
                        "invalidation rules: repro.core.jclient docstring)")
    p.add_argument("--fleet-cache", default="off",
                   choices=["off", "serve", "relay"],
                   help="fleet-wide artifact store: clients missing both "
                        "local cache tiers fetch peers' compiled artifacts "
                        "through the host instead of recompiling (serve: "
                        "host keeps a blob cache; relay: host forwards "
                        "fetches to the resident peer), so N clients x F "
                        "fingerprints costs exactly F compiles")
    p.add_argument("--max-stale-tells", type=int, default=None,
                   help="with --async-search: discard precomputed asks "
                        "lagging the model by more than this many folded "
                        "tells (default: unbounded stale tolerance)")
    p.add_argument("--async-search", action="store_true",
                   help="precompute asks in a background worker and fold "
                        "tells in at ask boundaries (SearchDriver), so "
                        "model-based search math overlaps with client "
                        "evaluation instead of stalling the fleet")
    p.add_argument("--gp", default="incremental",
                   choices=["incremental", "refit", "jax"],
                   help="bayesopt/pal surrogate update: incremental = "
                        "rank-append Cholesky per tell (O(n^2), cached "
                        "across asks); refit = full O(n^3) refactor per "
                        "ask (pre-PR behaviour, for benchmarking); jax = "
                        "device-resident jitted fast path with fused pool "
                        "scoring and inducing points (see module docstring)")
    p.add_argument("--gp-inducing", type=int, default=5000,
                   help="--gp jax: inducing-point threshold — past this "
                        "many observations the active set is thinned to a "
                        "strided subset so ask latency stays flat")
    p.add_argument("--gp-refresh", type=int, default=None, metavar="K",
                   help="hyperparameter refresh: re-tune the GP lengthscale "
                        "every K tells, rebuilding the live factor in place "
                        "(any --gp mode; default: never)")
    return p.parse_args()


def make_build_fn(args, jc):
    """Workload adapter: TestConfig -> (Artifact, meta).  Injected into
    JClient — 'the workloads can be anything' (paper §III)."""
    import jax

    from repro.configs import SHAPES, get_arch
    from repro.launch.build import build_cell, build_generation
    from repro.launch.mesh import make_mesh_dp_tp
    from repro.roofline.analysis import summarize
    from repro.roofline.traffic import analytic_hbm_bytes_per_device

    def build(tc):
        arch = get_arch(tc.arch)
        flags = jc.build_flags(tc.knobs)
        dp, tp = jc.mesh_factors(tc.knobs)
        mesh = make_mesh_dp_tp(dp, tp)
        if tc.shape == "generate":
            from repro.configs.base import ShapeConfig

            pre_cell, dec_cell = build_generation(
                arch, mesh, flags, batch=1,
                prompt_len=args.prompt_len,
                max_len=args.prompt_len + args.gen_tokens + 1)
            pre = summarize(pre_cell.compiled, mesh.size)
            dec = summarize(dec_cell.compiled, mesh.size)
            pre.hbm_est_per_device = analytic_hbm_bytes_per_device(
                arch, ShapeConfig("p", "prefill", args.prompt_len, 1),
                flags, mesh.size, dp, tp)
            dec.hbm_est_per_device = analytic_hbm_bytes_per_device(
                arch, ShapeConfig("d", "decode",
                                  args.prompt_len + args.gen_tokens + 1, 1),
                flags, mesh.size, dp, tp)
            return pre, {"decode_artifact": dec,
                         "n_decode_tokens": args.gen_tokens}
        shape = SHAPES[tc.shape]
        cell = build_cell(arch, shape, mesh, flags)
        art = summarize(cell.compiled, mesh.size)
        art.hbm_est_per_device = analytic_hbm_bytes_per_device(
            arch, shape, flags, mesh.size, dp, tp,
            optimizer=cell.meta.get("optimizer", "adamw"))
        return art, {}

    return build


def generation_space(arch, chips):
    """Knob space for the paper's generation workload (batch=1 ⇒ dp=1)."""
    from repro.core.space import DesignSpace, Knob, KIND_HW, KIND_SW
    from repro.roofline import hw as hwmod

    knobs = [
        Knob("clock_scale", hwmod.CLOCK_LADDER, KIND_HW),
        Knob("hbm_scale", hwmod.HBM_LADDER, KIND_HW),
        Knob("ici_scale", hwmod.ICI_LADDER, KIND_HW),
        Knob("dp_degree", (1,), KIND_SW),
        Knob("dtype", ("bfloat16",), KIND_SW),
    ]
    if arch.n_heads:
        knobs += [Knob("attn_block_q", (128, 256, 512), KIND_SW),
                  Knob("attn_block_kv", (128, 256, 512), KIND_SW)]
    if arch.ssm_state:
        knobs += [Knob("ssd_chunk", (128, 256, 512), KIND_SW)]
    return DesignSpace(knobs)


def main():
    args = parse_args()
    from repro.configs import get_arch, SHAPES
    from repro.core import (ALGORITHMS, JClient, JConfig, JHost, ResultStore,
                            transport, tpu_pod_space, hypervolume)

    arch = get_arch(args.workload)
    if args.shape == "generate":
        space = generation_space(arch, args.chips)
    else:
        space = tpu_pod_space(arch, SHAPES[args.shape], n_chips=args.chips)
    jc = JConfig(space, n_chips=args.chips)
    print(f"[explore] space size = {space.size()} "
          f"({len(space.knobs)} knobs); workload={args.workload}/{args.shape}")

    pair = transport.LoopbackPair(args.clients, codec=args.codec)
    build_fn = make_build_fn(args, jc)
    # each client gets its own persistent-cache subtree, like each board
    # owning its own disk on a real fleet
    fleet_mode = None if args.fleet_cache == "off" else args.fleet_cache
    clients = [JClient(jc, build_fn, transport=pair.client(i), client_id=i,
                       cache_dir=(None if args.cache_dir is None else
                                  os.path.join(args.cache_dir, f"client{i}")),
                       fleet_mode=fleet_mode)
               for i in range(args.clients)]
    threads = [threading.Thread(target=c.serve,
                                kwargs=dict(poll_s=0.1, idle_limit_s=None),
                                daemon=True)
               for c in clients]
    for t in threads:
        t.start()

    # pre-seed the CSV schema so a leading timeout/failure can't narrow it
    store = ResultStore(csv_path=args.out,
                        knob_names=[k.name for k in space],
                        metric_names=("time_s", "power_w"))
    host = JHost(pair.host(), store, timeout_s=args.timeout, poll_s=0.05)
    algo_kw = ({"gp_mode": args.gp,
                "hyper_refresh_every": args.gp_refresh,
                "inducing_threshold": args.gp_inducing}
               if args.algorithm in ("bayesopt", "pal") else {})
    fleet_store = None
    if fleet_mode is not None:
        from repro.core import FleetArtifactStore

        fleet_store = FleetArtifactStore(mode=fleet_mode)
    algo = ALGORITHMS[args.algorithm](space, seed=args.seed, **algo_kw)
    search = algo
    if args.async_search:
        from repro.core import SearchDriver

        search = SearchDriver(algo, mode="async",
                              max_stale_tells=args.max_stale_tells)
    t0 = time.time()
    try:
        host.explore(search, args.workload, args.shape, args.samples,
                     objectives=("time_s", "power_w"), progress=True,
                     batch_size=args.batch_size, dispatch=args.dispatch,
                     chunk_budget_ms=args.chunk_budget_ms,
                     affinity=args.affinity,
                     fingerprint_fn=(jc.cache_key if args.affinity != "off"
                                     or args.speculate_at is not None
                                     or args.speculate_slow_mult is not None
                                     or fleet_store is not None
                                     else None),
                     speculate_frac=args.speculate_at,
                     speculate_slow_mult=args.speculate_slow_mult,
                     pipeline_depth=args.pipeline_depth,
                     fleet_store=fleet_store)
    finally:
        if search is not algo:
            print(f"[explore] search driver: {search.stats()}")
            search.close()
    host.stop_clients()
    dt = time.time() - t0

    ok = store.ok_records()
    pts = store.objective_matrix(["time_s", "power_w"])
    front = store.pareto_front(["time_s", "power_w"])
    ref = pts.max(0) * 1.1
    compiles = sum(c.n_compiled for c in clients)
    print(f"[explore] {len(ok)} configs in {dt:.1f}s "
          f"({len(ok) / max(dt, 1e-9):.1f} evals/s; {compiles} compiles, "
          f"{len(ok)-compiles} cache hits)")
    if args.cache_dir is not None or fleet_store is not None:
        from repro.launch.report import cache_effectiveness

        line, _ = cache_effectiveness(
            [c.cache_info() for c in clients],
            fleet_store.stats() if fleet_store is not None else None)
        print(f"[explore] {line}")
    print(f"[explore] pareto front size = {len(front)}, "
          f"hypervolume = {hypervolume(pts, ref):.4g}")
    print(f"[explore] time range  [{pts[:,0].min():.3f}, {pts[:,0].max():.3f}] s")
    print(f"[explore] power range [{pts[:,1].min():.1f}, {pts[:,1].max():.1f}] W")
    print(f"[explore] results -> {args.out}")


if __name__ == "__main__":
    main()
