from repro.parallel.sharding import ShardingPolicy
