"""Gradient compression: int8 quantisation with error feedback.

Two forms:
  * ``ef_compress_tree`` — Q/DQ transform with an error-feedback residual
    carried in the train state (Seide et al. 2014 / Karimireddy et al. 2019).
    Under jit+SPMD the all-reduce XLA synthesises still runs at full
    precision, but the *numerics* of compressed training are exact, so
    convergence behaviour can be validated on this container.
  * ``psum_int8`` — the collective-level variant for shard_map data-parallel
    sections: quantise → integer psum → dequantise, which is what actually
    shrinks the wire bytes on a real pod (8/32 of the fp32 gradient volume;
    the roofline collective term scales accordingly).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8.  Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads: Any, ef_state: Any) -> Tuple[Any, Any]:
    """Error-feedback Q/DQ: g' = Q(g + e);  e' = (g + e) - g'."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = _quantize(corrected)
        dq = _dequantize(q, s)
        return dq, corrected - dq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))


def psum_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Compressed all-reduce for use inside shard_map: int8 on the wire.

    A shared scale (global absmax, one scalar all-reduce) keeps the integer
    sum exact to dequantise; wire volume is 1/4 of fp32 + one scalar."""
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
