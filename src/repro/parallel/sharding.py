"""Sharding policy: DP/FSDP over ``data`` (× ``pod``), TP over ``model``,
SP on the residual stream, EP for MoE experts, sequence-sharded KV caches.

Rules are path-based over the param pytree.  Dims that don't divide the axis
size fall back to GSPMD's padded (uneven) sharding — jit/SPMD supports this;
the padding waste (e.g. llama4's 40 q-heads on a 16-way model axis) is
visible in the roofline table and discussed in DESIGN.md.

Decode KV caches are sharded over the *sequence* axis of the cache on the
``model`` axis (flash-decode/split-K adapted to the mesh): attention logits
are computed on sequence shards, and XLA SPMD inserts the small all-reduces
for the softmax statistics and the weighted-value sum.  This is what makes
``long_500k`` (batch=1) scale — batch parallelism is unavailable, sequence
parallelism isn't.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardingPolicy:
    def __init__(self, mesh: Mesh, *, fsdp: bool = True, sp: bool = True):
        self.mesh = mesh
        names = mesh.axis_names
        self.tp_axis = "model" if "model" in names else None
        data_axes = tuple(a for a in ("pod", "data") if a in names)
        self.dp_axes: Tuple[str, ...] = data_axes
        self.fsdp = fsdp
        self.sp = sp

    # -- helpers ---------------------------------------------------------------
    def _axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            if a is not None:
                n *= self.mesh.shape[a]
        return n

    def _fits(self, dim: int, axes) -> Optional[Any]:
        """Use the axis only if it divides the dim exactly — jit in_shardings
        require even tiling.  Non-divisible dims (llama4's 40 q-heads on a
        16-way model axis, glm4's 2 kv-heads) replicate on that dim; the
        surrounding dims still shard, see DESIGN.md §6."""
        if axes is None:
            return None
        if dim % self._axis_size(axes) == 0:
            return axes
        return None

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    @property
    def dp(self):
        return self.dp_axes if self.dp_axes else None

    @property
    def fsdp_axes(self):
        return self.dp_axes if (self.fsdp and self.dp_axes) else None

    # -- param rules -------------------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """Right-aligned trailing-dim rules; leading axes (e.g. the scanned
        (n_groups,) stack axis) are never sharded."""
        tp, fs = self.tp_axis, self.fsdp_axes
        f = self._fits

        def right(*trailing) -> P:
            return P(*([None] * (len(shape) - len(trailing)) + list(trailing)))

        if "embed" in path and path.endswith("table"):        # (V, D)
            return right(f(shape[-2], tp), f(shape[-1], fs))
        if path.endswith("head/w"):                            # (D, V)
            return right(f(shape[-2], fs), f(shape[-1], tp))
        if path.endswith("frontend/proj"):
            return right(f(shape[-2], fs), f(shape[-1], tp))
        if re.search(r"mixer/w[qkv]$", path):                  # (D, H, dh)
            return right(f(shape[-3], fs), f(shape[-2], tp), None)
        if path.endswith("mixer/wo"):                          # (H, dh, D)
            return right(f(shape[-3], tp), None, f(shape[-1], fs))
        if re.search(r"(mlp|shared)/wi_(gate|up)$", path):     # (D, F)
            return right(f(shape[-2], fs), f(shape[-1], tp))
        if re.search(r"(mlp|shared)/wo$", path):               # (F, D)
            return right(f(shape[-2], tp), f(shape[-1], fs))
        if re.search(r"experts/wi_(gate|up)$", path):          # (E, D, Fe)
            return right(f(shape[-3], tp), f(shape[-2], fs), None)
        if path.endswith("experts/wo"):                        # (E, Fe, D)
            return right(f(shape[-3], tp), None, f(shape[-1], fs))
        if path.endswith("router"):                            # (D, E)
            return right(f(shape[-2], fs), None)
        if re.search(r"mixer/w(z|x|b|c|dt)$", path) or path.endswith("out_proj"):
            return right(f(shape[-2], fs), f(shape[-1], tp))  # mamba (D, X)
        if re.search(r"conv_[xbc]$", path):                    # (C, K)
            return right(f(shape[-2], tp), None)
        # 1-D norms / biases / A_log etc: replicate
        return P()

    def param_shardings(self, params_treedef_shapes) -> Any:
        """Map a pytree of ShapeDtypeStructs/arrays → NamedShardings."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_treedef_shapes)
        out = []
        for path, leaf in flat:
            spath = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            out.append(self.sharding(self.param_spec(spath, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def param_specs_tree(self, params_shapes) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
        out = []
        for path, leaf in flat:
            spath = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            out.append(self.param_spec(spath, leaf.shape))
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- activation constraints (used inside the model) ----------------------------
    def constrain_residual(self, x):
        """(B, S, D) residual stream: batch over data; seq over model if SP."""
        if x.ndim != 3:
            return x
        b, s, _ = x.shape
        bspec = self._fits(b, self.dp)
        sspec = self._fits(s, self.tp_axis) if (self.sp and s > 1) else None
        return jax.lax.with_sharding_constraint(
            x, self.sharding(P(bspec, sspec, None)))

    def constrain_attn_q(self, q):
        """(B, S, H, dh): seq-sharded over model with SP (K/V stay gathered);
        otherwise shard heads over model when divisible."""
        b, sq, h, _ = q.shape
        if self.sp and sq > 1:
            spec = P(self._fits(b, self.dp), self._fits(sq, self.tp_axis), None, None)
        else:
            spec = P(self._fits(b, self.dp), None, self._fits(h, self.tp_axis), None)
        return jax.lax.with_sharding_constraint(q, self.sharding(spec))

    def constrain_attn_kv(self, k):
        """(B, S, Hkv, dh): replicated over model under SP (GQA K/V are small);
        head-sharded when SP is off and the head count divides."""
        b, skv, hkv, _ = k.shape
        if self.sp and skv > 1:
            spec = P(self._fits(b, self.dp), None, None, None)
        else:
            spec = P(self._fits(b, self.dp), None, self._fits(hkv, self.tp_axis), None)
        return jax.lax.with_sharding_constraint(k, self.sharding(spec))

    def constrain_logits(self, x):
        b = x.shape[0]
        v = x.shape[-1]
        spec = [self._fits(b, self.dp)] + [None] * (x.ndim - 2) + [self._fits(v, self.tp_axis)]
        return jax.lax.with_sharding_constraint(x, self.sharding(P(*spec)))

    def constrain_expert_buffer(self, buf):
        """(g, E, C, D) — groups over data, experts over model (device-local
        dispatch grid); legacy 3-D (E, C, D) shards experts only."""
        if buf.ndim == 4:
            spec = P(self._fits(buf.shape[0], self.dp),
                     self._fits(buf.shape[1], self.tp_axis), None, None)
        else:
            spec = P(self._fits(buf.shape[0], self.tp_axis), None, None)
        return jax.lax.with_sharding_constraint(buf, self.sharding(spec))

    def constrain_group_local(self, t):
        """(g, …): sharded on the group (data) dim only — scatter/gather on
        the trailing dims are then provably device-local per group."""
        spec = P(self._fits(t.shape[0], self.dp), *([None] * (t.ndim - 1)))
        return jax.lax.with_sharding_constraint(t, self.sharding(spec))

    def moe_groups(self, batch: int) -> int:
        """Group-local MoE dispatch group count (= data-parallel degree)."""
        n = self._axis_size(self.dp)
        return n if (n > 1 and batch % n == 0) else 1

    def constrain_tokens_for_moe(self, x):
        """(B, S, D) purely batch-sharded (groups must own contiguous rows)."""
        return jax.lax.with_sharding_constraint(
            x, self.sharding(P(self._fits(x.shape[0], self.dp), None, None)))

    # -- data / cache shardings ------------------------------------------------------
    def batch_spec(self, leaf_shape: Tuple[int, ...]) -> P:
        b = leaf_shape[0]
        return P(self._fits(b, self.dp), *([None] * (len(leaf_shape) - 1)))

    def batch_shardings(self, batch) -> Any:
        return jax.tree.map(lambda l: self.sharding(self.batch_spec(l.shape)), batch)

    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """KV caches (…, B, S, Hkv, dh): seq-shard over model, batch over data.
        Mamba caches: batch over data, heads/channels over model.  A leading
        (n_groups,) scan axis may be present."""
        lead = len(shape) - 4
        if path.endswith("/k") or path.endswith("/v"):
            b, s, hkv, dh = shape[lead:]
            bspec = self._fits(b, self.dp)
            # batch=1 (long_500k): fold the idle data/pod axes into the
            # sequence sharding so all 256/512 chips hold cache shards.
            seq_axes = (self.tp_axis,) if bspec is not None else (
                tuple(self.dp_axes) + (self.tp_axis,))
            seq_axes = tuple(a for a in seq_axes if a is not None) or None
            return P(*([None] * lead), bspec,
                     self._fits(s, seq_axes), None, None)
        if path.endswith("state"):                    # (B, H, P, N)
            b, h = shape[lead], shape[lead + 1]
            return P(*([None] * lead), self._fits(b, self.dp),
                     self._fits(h, self.tp_axis), None, None)
        if path.endswith("conv"):                     # (B, K-1, C)
            lead = len(shape) - 3
            b, _, c = shape[lead:]
            return P(*([None] * lead), self._fits(b, self.dp), None,
                     self._fits(c, self.tp_axis))
        return P()

    def cache_shardings(self, caches) -> Any:
        flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
        out = []
        for path, leaf in flat:
            spath = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            out.append(self.sharding(self.cache_spec(spath, leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def replicated(self) -> NamedSharding:
        return self.sharding(P())
