"""Pipeline parallelism: GPipe-style microbatch pipeline over a ``pipe`` mesh
axis using shard_map + collective_permute.

The production configs default to FSDP+TP (a 256-chip v5e pod favours 2-D
sharding — see DESIGN.md §6), but PP is a first-class option for meshes where
a pod axis is better used as a pipeline: stage the layer stack, stream
microbatches, and rotate activations ring-wise.  Tested on small host meshes
in tests/test_pipeline.py.

The schedule is the classic GPipe loop unrolled as a lax.scan over
(n_micro + n_stages - 1) ticks; each tick every stage processes one resident
microbatch then collective_permutes its output to the next stage.  Bubble
fraction = (S-1)/(M+S-1), reported by ``bubble_fraction``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(mesh: Mesh, axis: str, stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   stage_params: Any, x_micro: jnp.ndarray) -> jnp.ndarray:
    """Run ``stage_fn`` as a pipeline over ``axis``.

    stage_params: pytree whose leaves carry a leading (n_stages,) axis —
      stage s uses leaf[s] (sharded onto the pipe axis by shard_map).
    x_micro: (n_micro, mb, ...) microbatched input, replicated across stages.
    Returns (n_micro, mb, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]

    def per_stage(params, xs):
        # inside shard_map: params leaves have leading dim 1 (this stage's slice)
        params = jax.tree.map(lambda l: l[0], params)
        stage = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, inflight = carry
            # stage 0 injects microbatch t (if still available); others take
            # the activation handed over from the previous stage.
            inject = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            cur = jnp.where(stage == 0, inject, inflight)
            out = stage_fn(params, cur)
            # pass to next stage (ring; the wrap-around edge is ignored)
            nxt = jax.lax.ppermute(
                out, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # final stage records its finished microbatch m = t - (S-1)
            m = t - (n_stages - 1)
            valid = (m >= 0) & (m < n_micro) & (stage == n_stages - 1)
            buf = jax.lax.cond(
                valid,
                lambda b: jax.lax.dynamic_update_index_in_dim(
                    b, out, jnp.clip(m, 0, n_micro - 1), axis=0),
                lambda b: b, buf)
            return (buf, nxt), None

        (buf, _), _ = jax.lax.scan(tick, (buf, jnp.zeros_like(xs[0])),
                                   jnp.arange(n_ticks))
        # broadcast final-stage results to all stages so the output is
        # replicated (masked psum: only the last stage contributes)
        buf = jax.lax.psum(
            jnp.where(stage == n_stages - 1, buf, jnp.zeros_like(buf)), axis)
        return buf

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_micro)
