from repro.data.pipeline import DataConfig, SyntheticLM, device_put_batch
