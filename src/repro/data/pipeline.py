"""Deterministic synthetic data pipeline.

A seeded Zipf-ish token stream (long-tailed like natural text) packed into
fixed-length training examples with next-token labels.  Deterministic per
(seed, step) — resuming from a checkpoint at step N reproduces exactly the
batches an uninterrupted run would have seen (tested), which is what makes
checkpoint/restart bit-exact end-to-end.

Frontend-stub batches (vision/audio) synthesise the precomputed embeddings
the assignment prescribes for [vlm]/[audio] archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


class SyntheticLM:
    """Stateless: batch(step) is a pure function of (cfg, arch, step)."""

    def __init__(self, arch: ArchConfig, cfg: DataConfig):
        self.arch = arch
        self.cfg = cfg
        # Zipf over the vocab, renormalised (heavy head like natural text)
        v = arch.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = p / p.sum()

    def batch(self, step: int) -> Dict[str, Any]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))
        b, s = self.cfg.batch, self.cfg.seq_len
        toks = rng.choice(self.arch.vocab_size, size=(b, s + 1), p=self._p)
        toks = toks.astype(np.int32)
        out: Dict[str, Any] = {"labels": toks[:, 1:]}
        if self.arch.frontend == "vision":
            f = self.arch.n_frontend_tokens
            out["tokens"] = toks[:, : s - f]
            out["image_embeds"] = rng.standard_normal(
                (b, f, self.arch.d_model), dtype=np.float32)
        elif self.arch.frontend == "audio":
            out["frame_embeds"] = rng.standard_normal(
                (b, s, self.arch.d_model), dtype=np.float32)
        else:
            out["tokens"] = toks[:, :s]
        return out

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def device_put_batch(batch: Dict[str, Any], policy=None) -> Dict[str, Any]:
    import jax

    if policy is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    shardings = policy.batch_shardings(batch)
    return jax.tree.map(jax.device_put, batch, shardings)
