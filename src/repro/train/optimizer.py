"""Optimizers (pure pytree, no optax): AdamW, Adafactor, schedules, clipping.

Adafactor matters at scale: AdamW state is 8 B/param fp32, which cannot fit
llama4-maverick-400b training on a single 256-chip v5e pod (4 TB pod HBM);
Adafactor's factored second moment is O(rows+cols) and makes that cell fit —
the roofline table reports both (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# Optimizer interface
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def adamw(lr: Callable, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = lr(step)
        bc1 = 1 - b1 ** stepf
        bc2 = 1 - b2 ** stepf

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([x[0] for x in new])
        new_m = treedef.unflatten([x[1] for x in new])
        new_v = treedef.unflatten([x[2] for x in new])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adafactor(lr: Callable, eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, min_dim_factored: int = 128) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018), no momentum."""

    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored and p.shape[-2] >= min_dim_factored

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"slots": jax.tree.map(one, params,
                                      is_leaf=lambda x: isinstance(x, jnp.ndarray))}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        decay = 1.0 - stepf ** -0.8
        lr_t = lr(step)

        def upd(g, slot, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = decay * slot["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * slot["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                u = g * jax.lax.rsqrt(vr[..., None] / denom[..., None])
                u = u * jax.lax.rsqrt(vc[..., None, :])
                new_slot = {"vr": vr, "vc": vc}
            else:
                v = decay * slot["v"] + (1 - decay) * g2
                u = g * jax.lax.rsqrt(v)
                new_slot = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr_t * u
            if weight_decay and p.ndim >= 2:
                newp = newp - lr_t * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), new_slot

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["slots"])
        flat_p = treedef.flatten_up_to(params)
        new = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        return (treedef.unflatten([x[0] for x in new]),
                {"slots": treedef.unflatten([x[1] for x in new])})

    return Optimizer(init, update)


OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor}
