from repro.train.optimizer import adamw, adafactor, cosine_schedule, OPTIMIZERS
from repro.train.train_step import TrainStepConfig, make_train_step, init_train_state, train_state_shapes
from repro.train.checkpoint import CheckpointManager
