"""Checkpointing: atomic, sharded-logical, async, keep-k, elastic restore.

Layout:  <dir>/step_<N>/  with one .npy per pytree leaf (flattened path keys)
plus manifest.json (treedef paths, shapes, dtypes, step).  Writes go to a
``.tmp-`` directory first and are renamed into place — a torn write can never
be mistaken for a valid checkpoint (the fault-tolerance contract).

Restore is *elastic*: arrays are loaded as host numpy and re-placed with
whatever shardings the (possibly different-sized) new mesh policy provides,
so a run checkpointed on one mesh resumes on another (tests cover 1→8
devices and mesh reshapes).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def _unflatten_like(template: Any, values: Dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in values:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(values[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> None:
        # snapshot to host memory synchronously (donation-safe), write async
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat)

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = os.path.join(self.directory, f".tmp-step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, shardings: Any = None) -> Any:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        values = {}
        for key, meta in manifest["leaves"].items():
            values[key] = np.load(os.path.join(d, meta["file"]))
        tree = _unflatten_like(template, values)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree
