"""Train-step factory: loss → grads (microbatched) → clip → optimizer.

Gradient accumulation splits the global batch into ``microbatch`` slices and
lax.scans over them, accumulating fp32 grads — the standard memory/throughput
knob.  Optional int8 error-feedback gradient compression is applied before
the optimizer (see parallel/compress.py for the collective-level variant).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatch: int = 1
    max_grad_norm: float = 1.0
    grad_compress: bool = False


def make_train_step(model, optimizer: Optimizer,
                    cfg: TrainStepConfig = TrainStepConfig(), policy=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "step", ["ef"]}.
    """

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if cfg.microbatch > 1:
            def slice_mb(x, i):
                mb = x.shape[0] // cfg.microbatch
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def body(carry, i):
                acc, loss_acc = carry
                mb_batch = jax.tree.map(lambda x: slice_mb(x, i), batch)
                loss, metrics, grads = grads_of(params, mb_batch)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)),
                jnp.arange(cfg.microbatch),
                unroll=getattr(model.flags, "unroll", False))
            grads = jax.tree.map(lambda g: g / cfg.microbatch, grads)
            loss = loss_sum / cfg.microbatch
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if policy is not None and getattr(model.flags, "grad_rs", False):
            # pin grads to the param sharding so XLA lowers the gradient
            # reduction as reduce-scatter into the shards rather than a
            # full all-reduce followed by a slice (§Perf hillclimb)
            grads = jax.lax.with_sharding_constraint(
                grads, policy.param_shardings(grads))
        if cfg.grad_compress:
            from repro.parallel.compress import ef_compress_tree

            grads, ef = ef_compress_tree(grads, state["ef"])
        grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
        new_params, new_opt = optimizer.update(grads, state["opt"], params, state["step"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        if cfg.grad_compress:
            new_state["ef"] = ef
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, out_metrics

    return train_step


def init_train_state(model, optimizer: Optimizer, rng,
                     cfg: TrainStepConfig = TrainStepConfig()) -> Dict[str, Any]:
    params = model.init(rng)
    state = {"params": params, "opt": optimizer.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.grad_compress:
        from repro.parallel.compress import ef_init

        state["ef"] = ef_init(params)
    return state


def train_state_shapes(model, optimizer: Optimizer,
                       cfg: TrainStepConfig = TrainStepConfig()):
    """eval_shape of init_train_state — dry-run use, no allocation."""
    return jax.eval_shape(
        lambda k: init_train_state(model, optimizer, k, cfg), jax.random.key(0))
