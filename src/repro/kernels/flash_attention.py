"""Flash attention forward — Pallas TPU kernel.

Causal (optionally sliding-window) GQA attention with online softmax.

Tiling: grid = (B·H, n_q_blocks, n_kv_blocks); the kv axis is the minor
(sequential) grid dimension, so the online-softmax running state (m, l, acc)
lives in VMEM scratch and is carried across kv iterations for a fixed q block.
Block shapes are (block_q × d_head) for Q/O and (block_kv × d_head) for K/V —
MXU-aligned when block sizes and d_head are multiples of 128 (d_head=64 archs
still lower; the compiler pads lanes).

VMEM working set per program ≈ (2·block_q·d + 2·block_kv·d + block_q·block_kv)
× 4 B — asserted against a 16 MiB budget in ``ops.flash_attention``.

GQA is expressed in the K/V index maps (q-head → kv-head is h // n_rep), so
K/V blocks are fetched once per kv head group without materialising the
head-repeated tensors.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, block_q, block_kv, seq_len_q,
                  seq_len_kv, n_kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    # Block-level reachability: causal ⇒ kv block must start at/before the last
    # q row; window ⇒ kv block must end after the first q row's window start.
    needed = k_start < seq_len_kv
    if causal:
        needed &= k_start <= q_start + block_q - 1
    if window:
        needed &= (k_start + block_kv - 1) >= (q_start - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale              # (bq, d)
        k = k_ref[0].astype(jnp.float32)                      # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bkv)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = cols < seq_len_kv
        if causal:
            mask &= cols <= rows
        if window:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, block_q=256,
                        block_kv=256, interpret=False):
    """q: (BH, Sq, d) flattened over q heads; k, v: (BHkv, Skv, d).

    BH must be a multiple of BHkv (GQA).  Returns o: (BH, Sq, d).
    Sq/Skv need not be block multiples (padded internally by the caller).
    """
    bh, sq, d = q.shape
    bhkv, skv, _ = k.shape
    assert bh % bhkv == 0
    n_rep = bh // bhkv
    nq = sq // block_q
    nk = skv // block_kv
    assert sq % block_q == 0 and skv % block_kv == 0

    kernel = functools.partial(
        _flash_kernel, scale=d ** -0.5, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_len_q=sq, seq_len_kv=skv,
        n_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, n_rep=n_rep: (b // n_rep, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j, n_rep=n_rep: (b // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
