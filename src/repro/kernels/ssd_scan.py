"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

grid = (B, H, n_chunks); the chunk axis is the minor (sequential) grid
dimension, so the (P × N) per-head SSM state lives in VMEM scratch and is
carried across chunk iterations — the inter-chunk recurrence costs no HBM
round-trips.  Each program computes one chunk of one head:

  intra-chunk:  Y += tril((C·Bᵀ) ∘ exp(cum_i − cum_j) ∘ dt_j) @ X   (MXU matmuls)
  state-in:     Y += (C @ stateᵀ) ∘ exp(cum)
  state-out:    state = state·exp(total) + (X ∘ dt·exp(total−cum))ᵀ @ B

VMEM per program ≈ (Q·P + 2·Q·N + Q·Q + P·N) × 4 B; with Q=256, P=64, N=128
that is ~0.6 MiB — far under budget, so chunks can be widened via the JConfig
``ssd_chunk`` knob.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, dt_ref, y_ref, state_ref,
                state_scr, *, n_chunks, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    bb = b_ref[0].astype(jnp.float32)              # (Q, N)
    cc = c_ref[0].astype(jnp.float32)              # (Q, N)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)

    cum = jnp.cumsum(a)                            # (Q,)
    cb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)     # (Q, Q)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    sm = jnp.where(cols <= rows, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(sm, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)      # (Q, P)

    state = state_scr[...]                         # (P, N)
    y += jax.lax.dot_general(cc, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]

    total = cum[-1]
    rem = jnp.exp(total - cum)                     # (Q,)
    dx = x * (dt * rem)[:, None]                   # (Q, P)
    new_state = state * jnp.exp(total) + jax.lax.dot_general(
        dx, bb, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_scr[...] = new_state

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_ref[0, 0] = new_state


def ssd_scan_fwd(x, a_log, b, c, dt, *, chunk=256, interpret=False):
    """x: (B,S,H,P); a_log, dt: (B,S,H); b, c: (B,S,N).  S % chunk == 0.

    Returns (y (B,S,H,P), state (B,H,P,N) fp32).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, n_chunks=nc, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a_log, b, c, dt)
