"""jit'd public wrappers around the Pallas kernels.

Off-TPU (this container) the kernels execute in interpret mode; on a real TPU
backend they lower through Mosaic.  Wrappers handle layout (B,S,H,D) ↔ kernel
layout, sequence padding to block multiples, and VMEM-budget assertions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import topk_gating as _tg

VMEM_BUDGET_BYTES = 16 * 1024 * 1024  # v5e VMEM per core


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention_vmem_bytes(block_q, block_kv, d):
    return 4 * (2 * block_q * d + 2 * block_kv * d + block_q * block_kv + 2 * block_q)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=256, block_kv=256):
    """q: (B, S, H, d); k, v: (B, S, Hkv, d) -> (B, S, H, d)."""
    assert flash_attention_vmem_bytes(block_q, block_kv, q.shape[-1]) < VMEM_BUDGET_BYTES
    b, s, h, d = q.shape
    hkv = k.shape[2]
    block_q = min(block_q, max(16, 1 << (s - 1).bit_length()))
    block_kv = min(block_kv, max(16, 1 << (s - 1).bit_length()))
    pad = (-s) % max(block_q, block_kv)
    qt = q.swapaxes(1, 2).reshape(b * h, s, d)
    kt = k.swapaxes(1, 2).reshape(b * hkv, s, d)
    vt = v.swapaxes(1, 2).reshape(b * hkv, s, d)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pad), (0, 0)))
    o = _fa.flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                                block_q=block_q, block_kv=block_kv,
                                interpret=_interpret())
    o = o[:, :s].reshape(b, h, s, d).swapaxes(1, 2)
    return o


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, a_log, b, c, dt, *, chunk=256):
    """Chunked SSD; pads S to a chunk multiple (dt=0 ⇒ pads are inert)."""
    s = x.shape[1]
    chunk = min(chunk, max(8, 1 << (s - 1).bit_length()))
    pad = (-s) % chunk
    if pad:
        padf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, a_log, b, c, dt = map(padf, (x, a_log, b, c, dt))
    y, state = _ssd.ssd_scan_fwd(x, a_log, b, c, dt, chunk=chunk,
                                 interpret=_interpret())
    return y[:, :s], state


@functools.partial(jax.jit, static_argnames=("k", "block_t"))
def topk_gating(logits, k, *, block_t=1024):
    return _tg.topk_gating_fwd(logits, k, block_t=block_t, interpret=_interpret())
