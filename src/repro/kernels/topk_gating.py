"""Fused MoE router gating — Pallas TPU kernel.

softmax over expert logits + iterative top-k selection in one VMEM-resident
pass over a (block_t × E) tile.  Avoids the XLA lowering of lax.top_k (full
sort) for the small k (≤ 8) used by the assigned MoE archs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gating_kernel(logits_ref, p_ref, id_ref, *, k, n_experts):
    x = logits_ref[...].astype(jnp.float32)                  # (T, E)
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)

    cols = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    work = probs
    for i in range(k):  # k is small & static: unrolled argmax-and-mask
        top = jnp.max(work, axis=-1)                          # (T,)
        is_top = work == top[:, None]
        # break ties toward the smallest expert index
        idx = jnp.min(jnp.where(is_top, cols, n_experts), axis=-1)
        p_ref[:, i] = top
        id_ref[:, i] = idx.astype(jnp.int32)
        work = jnp.where(cols == idx[:, None], -1.0, work)


def topk_gating_fwd(logits, k, *, block_t=1024, interpret=False):
    """logits: (T, E) fp32 -> (top_p (T,k) fp32, top_ids (T,k) int32)."""
    t, e = logits.shape
    pad = (-t) % block_t
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
    tp = t + pad
    kernel = functools.partial(_gating_kernel, k=k, n_experts=e)
    p, ids = pl.pallas_call(
        kernel,
        grid=(tp // block_t,),
        in_specs=[pl.BlockSpec((block_t, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, k), jnp.float32),
            jax.ShapeDtypeStruct((tp, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
    return p[:t], ids[:t]
