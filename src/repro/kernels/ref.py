"""Pure-jnp oracles for every Pallas kernel (the test-suite ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B, Sq, H, d); k, v: (B, Skv, Hkv, d) -> (B, Sq, H, d)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    n_rep = h // hkv
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * d ** -0.5
    iq = jnp.arange(sq)[:, None] + (skv - sq)  # right-aligned if sq < skv
    ik = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= ik <= iq
    if window:
        mask &= (iq - ik) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, a_log, b, c, dt):
    """Sequential (non-chunked) SSD recurrence — the slowest, clearest oracle.

    x: (B,S,H,P); a_log, dt: (B,S,H); b, c: (B,S,N).
    Returns (y (B,S,H,P), state (B,H,P,N) fp32).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        xt, at, bt, ct, dtt = inp
        decay = jnp.exp(at.astype(jnp.float32))                       # (B,H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", xt.astype(jnp.float32), bt.astype(jnp.float32), dtt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    xs = (x.swapaxes(0, 1), a_log.swapaxes(0, 1), b.swapaxes(0, 1),
          c.swapaxes(0, 1), dt.swapaxes(0, 1))
    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), state


def topk_gating_ref(logits, k):
    """logits: (T, E) -> (top_p (T,k) fp32, top_ids (T,k) int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)
    return top_p, top_ids.astype(jnp.int32)
