"""Decoder stack: scan over pattern groups, heterogeneous layer support.

The layer list is ``pattern × n_groups (+ remainder)``; the scan body applies
one pattern group, so HLO size is O(|pattern|) regardless of depth.  Caches
returned by prefill / consumed by decode are pytrees whose 'scan' leaves carry
a leading (n_groups,) axis, matching the scanned params.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import attention, mamba2, moe
from repro.models.layers import (
    _normal,
    embed,
    embedding_init,
    lm_head,
    lm_head_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)


# ---------------------------------------------------------------------------
# Layer init / apply
# ---------------------------------------------------------------------------


def _layer_init(key, spec: LayerSpec, cfg: ArchConfig, dtype):
    km, kf = jax.random.split(key)
    p: Dict[str, Any] = {}
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"] = attention.attn_init(km, cfg, dtype)
    else:
        p["mixer"] = mamba2.mamba_init(km, cfg, dtype)
    if spec.ffn == "dense":
        f = cfg.d_ff if cfg.d_ff else cfg.moe_d_ff
        p["ffn"] = {"norm": rmsnorm_init(cfg.d_model, dtype),
                    "mlp": mlp_init(kf, cfg.d_model, f, dtype)}
    elif spec.ffn == "moe":
        p["ffn"] = moe.moe_init(kf, cfg, dtype)
    return p


def _layer_full(spec, p, x, cfg, flags, policy):
    """Full-seq layer.  Returns (x, aux, cache)."""
    window = cfg.sliding_window if spec.mixer == "attn_local" else 0
    if spec.mixer in ("attn", "attn_local"):
        h, cache = attention.full_attention(
            p["mixer"], x, cfg, window=window, impl=flags.attn_impl,
            attn_block_q=flags.attn_block_q, attn_block_kv=flags.attn_block_kv,
            policy=policy)
    else:
        h, cache = mamba2.mamba_block(p["mixer"], x, cfg, impl=flags.ssd_impl,
                                      unroll=flags.unroll)
    x = x + h
    if policy is not None:
        x = policy.constrain_residual(x)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "dense":
        x = x + mlp(p["ffn"]["mlp"], rmsnorm(p["ffn"]["norm"], x, cfg.norm_eps))
    elif spec.ffn == "moe":
        y, aux = moe.moe_ffn(p["ffn"], x, cfg, policy)
        x = x + y
    if policy is not None:
        x = policy.constrain_residual(x)
    return x, aux, cache


def _layer_decode(spec, p, x, cache, pos, cfg):
    window = cfg.sliding_window if spec.mixer == "attn_local" else 0
    if spec.mixer in ("attn", "attn_local"):
        h, cache = attention.decode_attention(p["mixer"], x, cache, pos, cfg, window=window)
    else:
        h, cache = mamba2.mamba_decode(p["mixer"], x, cache, cfg)
    x = x + h
    if spec.ffn == "dense":
        x = x + mlp(p["ffn"]["mlp"], rmsnorm(p["ffn"]["norm"], x, cfg.norm_eps))
    elif spec.ffn == "moe":
        y, _ = moe.moe_ffn(p["ffn"], x, cfg, None)
        x = x + y
    return x, cache


def _layer_empty_cache(spec, cfg, batch, seq_len, dtype):
    if spec.mixer in ("attn", "attn_local"):
        return attention.empty_cache(cfg, batch, seq_len, dtype)
    return mamba2.empty_mamba_cache(cfg, batch)


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------


def _group_layout(cfg: ArchConfig):
    g = len(cfg.pattern)
    return cfg.n_layers // g, cfg.n_layers % g  # (n_full_groups, remainder)


def stack_init(key, cfg: ArchConfig, dtype):
    n_groups, rem = _group_layout(cfg)
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = lm_head_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.frontend:
        params["frontend"] = {"proj": _normal(keys[2], (cfg.d_model, cfg.d_model), dtype)}

    specs = cfg.layer_specs()

    def one_group(gkey, group_specs):
        lkeys = jax.random.split(gkey, len(group_specs))
        return {f"l{i}": _layer_init(lkeys[i], s, cfg, dtype)
                for i, s in enumerate(group_specs)}

    if n_groups:
        gkeys = jax.random.split(keys[3], n_groups)
        # first_k_dense may make group 0's specs differ from the repeating
        # pattern; scanned groups must be homogeneous, so groups whose specs
        # deviate are moved to an unscanned 'head_layers' section.
        base = tuple(cfg.pattern)
        deviant = []
        homog = []
        for gi in range(n_groups):
            gspecs = specs[gi * len(base) : (gi + 1) * len(base)]
            (deviant if tuple(gspecs) != base else homog).append(gi)
        params["head_layers"] = {
            f"g{gi}": one_group(gkeys[gi], specs[gi * len(base) : (gi + 1) * len(base)])
            for gi in deviant
        }
        homog_keys = [gkeys[gi] for gi in homog]
        if homog:
            params["scan"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *[one_group(k, base) for k in homog_keys]
            )
    if rem:
        rkey = jax.random.fold_in(keys[3], 999)
        params["tail"] = one_group(rkey, specs[-rem:])
    return params


def _sections(cfg):
    """Yield (section, group_specs, scanned?) in layer order."""
    n_groups, rem = _group_layout(cfg)
    specs = cfg.layer_specs()
    base = tuple(cfg.pattern)
    out = []
    deviant = [gi for gi in range(n_groups)
               if tuple(specs[gi * len(base) : (gi + 1) * len(base)]) != base]
    for gi in deviant:
        out.append((f"head_layers/g{gi}", specs[gi * len(base) : (gi + 1) * len(base)], False))
    n_homog = n_groups - len(deviant)
    if n_homog:
        out.append(("scan", base, True))
    if rem:
        out.append(("tail", specs[-rem:], False))
    return out


def _get_section(params, name):
    node = params
    for part in name.split("/"):
        node = node[part]
    return node


def forward_full(params, x, cfg, flags, policy, want_cache):
    """x: (B,S,D) embedded input -> (hidden (B,S,D), aux, caches|None)."""
    caches: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)

    for name, gspecs, scanned in _sections(cfg):
        sec = _get_section(params, name)
        if scanned:
            def body(carry, gparams):
                xx, aux = carry
                gcache = {}
                for i, s in enumerate(gspecs):
                    xx, a, c = _layer_full(s, gparams[f"l{i}"], xx, cfg, flags, policy)
                    aux = aux + a
                    if want_cache:
                        gcache[f"l{i}"] = c
                return (xx, aux), (gcache if want_cache else None)

            if flags.remat != "none":
                body = jax.checkpoint(body, policy=flags.remat_policy())
            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), sec,
                                              unroll=flags.unroll)
            if want_cache:
                caches[name] = ys
        else:
            for i, s in enumerate(gspecs):
                x, a, c = _layer_full(s, sec[f"l{i}"], x, cfg, flags, policy)
                aux_total = aux_total + a
                if want_cache:
                    caches.setdefault(name, {})[f"l{i}"] = c
    return x, aux_total, (caches if want_cache else None)


def forward_decode(params, x, caches, pos, cfg, unroll=False):
    """x: (B,1,D) -> (hidden (B,1,D), new caches)."""
    new_caches: Dict[str, Any] = {}
    for name, gspecs, scanned in _sections(cfg):
        sec = _get_section(params, name)
        if scanned:
            def body(xx, inp):
                gparams, gcache = inp
                ncache = {}
                for i, s in enumerate(gspecs):
                    xx, ncache[f"l{i}"] = _layer_decode(s, gparams[f"l{i}"], xx, gcache[f"l{i}"], pos, cfg)
                return xx, ncache

            x, ys = jax.lax.scan(body, x, (sec, caches[name]), unroll=unroll)
            new_caches[name] = ys
        else:
            new_caches[name] = {}
            for i, s in enumerate(gspecs):
                x, c = _layer_decode(s, sec[f"l{i}"], x, caches[name][f"l{i}"], pos, cfg)
                new_caches[name][f"l{i}"] = c
    return x, new_caches


def empty_caches(cfg, batch, seq_len, dtype):
    out: Dict[str, Any] = {}
    n_groups, _ = _group_layout(cfg)
    specs = cfg.layer_specs()
    base = tuple(cfg.pattern)
    for name, gspecs, scanned in _sections(cfg):
        one = {f"l{i}": _layer_empty_cache(s, cfg, batch, seq_len, dtype)
               for i, s in enumerate(gspecs)}
        if scanned:
            n_homog = sum(1 for gi in range(n_groups)
                          if tuple(specs[gi * len(base) : (gi + 1) * len(base)]) == base)
            out[name] = jax.tree.map(
                lambda t: jnp.broadcast_to(t[None], (n_homog,) + t.shape), one)
        else:
            out[name] = one
    return out
