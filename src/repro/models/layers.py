"""Shared NN building blocks (pure JAX, no flax).

Params are plain nested dicts of jnp arrays.  Init functions take an explicit
PRNG key and return the param subtree; apply functions are pure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _normal(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def gated_rmsnorm(params, x, gate, eps=1e-6):
    """Mamba-2 style: normalise x * silu(gate)."""
    return rmsnorm(params, x * jax.nn.silu(gate), eps)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------


def embedding_init(key, vocab, d, dtype):
    return {"table": _normal(key, (vocab, d), dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def lm_head_init(key, d, vocab, dtype):
    return {"w": _normal(key, (d, vocab), dtype)}


def lm_head(params, x):
    return jnp.einsum("...d,dv->...v", x, params["w"])


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": _normal(k1, (d, f), dtype),
        "wi_up": _normal(k2, (d, f), dtype),
        "wo": _normal(k3, (f, d), dtype),
    }


def mlp(params, x):
    g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
    u = jnp.einsum("...d,df->...f", x, params["wi_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head, theta):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta):
    """x: (..., S, H, d_head); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    inv = rope_freqs(d_head, theta)                       # (d_head/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Cross-entropy
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels):
    """logits (..., V) float; labels (...) int32.  Mean over all positions."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
