"""Fine-grained Mixture-of-Experts FFN (DeepSeek-MoE style).

Design (TPU adaptation, see DESIGN.md §6 and EXPERIMENTS.md §Perf):
  * top-k routing with softmax-renormalised weights over the selected experts;
  * **group-local dispatch**: tokens are reshaped to (g, T/g, D) where g is
    the data-parallel degree, and ranks/capacity/scatter are computed within
    each group.  The expert buffer (g, E, C, D) is sharded
    P(data, model, None, None), so the dispatch scatter and the per-expert
    matmuls are entirely device-local — device (i, j) holds group i's tokens
    for experts e_j and the weights of e_j.  Only the combine (token pulls
    its k expert outputs across the model axis) moves data, which XLA lowers
    as partial gathers + an all-reduce of the (g, T/g, D) output.  The naive
    global scatter-add variant lowers to an all-reduce of the *full* buffer
    per layer (~2.3 TiB/device/step for deepseek-moe-16b train_4k — measured,
    see §Perf) and is why group-locality is not optional at 32k context;
  * capacity is per group (locality-aware drop policy, standard for EP);
  * optional shared experts (always-on dense branch, DeepSeek convention);
  * Switch-style load-balance auxiliary loss returned to the caller.

Dropped tokens (over capacity) fall through the residual connection — the
standard capacity-factor contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, mlp, mlp_init, rmsnorm, rmsnorm_init


def moe_init(key, cfg, dtype):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "norm": rmsnorm_init(d, dtype),
        "router": _normal(k1, (d, e), jnp.float32),  # router kept fp32
        "experts": {
            "wi_gate": _normal(k2, (e, d, f), dtype),
            "wi_up": _normal(k3, (e, d, f), dtype),
            "wo": _normal(k4, (e, f, d), dtype),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(k5, d, cfg.n_shared_experts * f, dtype)
    return p


def expert_capacity(n_tokens, cfg):
    c = int(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    c = max(c, cfg.moe_top_k)
    return -(-c // 8) * 8  # round up to a multiple of 8 (lane friendliness)


def _rank_in_expert(flat_ids, e):
    """Position of each assignment within its expert's arrival order.

    flat_ids: (A,) int32.  Returns (A,) int32 rank.  O(A log A) via stable sort.
    """
    a = flat_ids.shape[0]
    order = jnp.argsort(flat_ids, stable=True)             # (A,)
    sorted_ids = flat_ids[order]
    seg_starts = jnp.searchsorted(sorted_ids, jnp.arange(e))
    rank_sorted = jnp.arange(a) - seg_starts[sorted_ids]
    return jnp.zeros((a,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))


def moe_ffn(params, x, cfg, policy=None):
    """x: (B, S, D) -> (out, aux_loss).  Routed + shared experts."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = b * s
    g = 1
    if policy is not None:
        g = policy.moe_groups(b)
        if g > 1:
            # tokens must be purely batch-sharded for group-local dispatch
            # (SP seq-sharding is re-established by the residual constraint)
            x = policy.constrain_tokens_for_moe(x)
    tl = t // g
    c = expert_capacity(tl, cfg)

    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    xt = h.reshape(g, tl, d)

    gate_logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                             params["router"])
    probs = jax.nn.softmax(gate_logits, axis=-1)            # (g, Tl, E)
    top_p, top_ids = jax.lax.top_k(probs, k)                # (g, Tl, k)
    weights = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- load-balance aux loss (Switch): E * <f_e * p_e> ----
    me = jnp.mean(probs, axis=(0, 1))
    one_hot_sel = jnp.sum(jax.nn.one_hot(top_ids, e, dtype=jnp.float32), axis=2)
    ce = jnp.mean(one_hot_sel, axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    # ---- group-local rank & slot ----
    flat_ids = top_ids.reshape(g, tl * k)
    rank = jax.vmap(lambda ids: _rank_in_expert(ids, e))(flat_ids)
    rank = rank.reshape(g, tl, k)
    keep = rank < c
    slot = jnp.where(keep, top_ids * c + rank, e * c)       # drops -> sentinel

    # ---- dispatch: scatter within each group (device-local) ----
    # The scatter target must be sharded on the group dim ONLY: with the
    # (E·C) dim unsharded the scatter is a per-group local op; re-sharding
    # the result onto the model axis afterwards is a local slice.  Sharding
    # the buffer over model *before* the scatter makes XLA replicate the
    # whole buffer per layer (measured: 3.8 TiB/dev all-gather — §Perf A2).
    buf = jnp.zeros((g, e * c + 1, d), xt.dtype)
    if policy is not None:
        buf = policy.constrain_group_local(buf)
    # vmap over the group dim lowers to a scatter with explicit batch dims,
    # which the SPMD partitioner partitions along `g`; the two-index-array
    # form buf.at[gi, slot] defeats it and replicates the buffer (§Perf A3).
    scatter1 = jax.vmap(lambda bb, idx, upd: bb.at[idx].add(upd))
    for i in range(k):  # k is small & static
        contrib = jnp.where(keep[:, :, i, None], xt, jnp.zeros_like(xt))
        buf = scatter1(buf, slot[:, :, i], contrib)
    buf = buf[:, : e * c].reshape(g, e, c, d)
    if policy is not None:
        buf = policy.constrain_expert_buffer(buf)

    # ---- expert computation (device-local: (data=g, model=e) grid) ----
    we = params["experts"]
    gate = jnp.einsum("gecd,edf->gecf", buf, we["wi_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, we["wi_up"])
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up, we["wo"])
    if policy is not None:
        y = policy.constrain_expert_buffer(y)

    # ---- combine: all-gather the (small) expert outputs over model, then
    # gather per group locally (predictable 2·E·C·D/g bytes per device) ----
    y_flat = jnp.concatenate(
        [y.reshape(g, e * c, d), jnp.zeros((g, 1, d), y.dtype)], axis=1)
    if policy is not None:
        y_flat = policy.constrain_group_local(y_flat)
    out = jnp.zeros((g, tl, d), x.dtype)
    gather1 = jax.vmap(lambda yf, idx: yf[idx])
    for i in range(k):
        gathered = gather1(y_flat, slot[:, :, i])
        out = out + gathered * (weights[:, :, i, None]
                                * keep[:, :, i, None]).astype(x.dtype)

    out = out.reshape(b, s, d)
    if policy is not None:
        out = policy.constrain_residual(out)
    if cfg.n_shared_experts:
        out = out + mlp(params["shared"], h)
    return out, aux
