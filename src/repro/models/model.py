"""Model facade: init / train-forward / prefill / decode built from ArchConfig.

``BuildFlags`` carries every knob that changes the lowered HLO (the JConfig
"software" knob subset); hardware-ladder knobs never reach this layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer
from repro.models.layers import embed, lm_head, rmsnorm, softmax_cross_entropy


@dataclasses.dataclass(frozen=True)
class BuildFlags:
    dtype: str = "bfloat16"            # activation/param dtype
    attn_impl: str = "xla"             # xla | flash (Pallas kernel)
    ssd_impl: str = "jnp"              # jnp | pallas
    remat: str = "selective"           # none | selective | full
    loss_chunks: int = 1               # chunked vocab-CE to cap logits memory
    attn_block_q: int = 256
    attn_block_kv: int = 256
    sp: bool = True                    # sequence-parallel residual stream
    fsdp: bool = True                  # shard params over data axes too
    grad_rs: bool = False              # constrain grads to param sharding
                                       # (reduce-scatter instead of all-reduce)
    unroll: bool = False               # unroll scans (shallow roofline builds)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def remat_policy(self):
        if self.remat == "selective":
            return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return None  # 'full': save nothing


class Model:
    def __init__(self, cfg: ArchConfig, flags: BuildFlags = BuildFlags(), policy=None):
        self.cfg = cfg
        self.flags = flags
        self.policy = policy

    # -- params ----------------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        return transformer.stack_init(rng, self.cfg, self.flags.jdtype)

    def init_shapes(self):
        """eval_shape of init — no allocation (used by the dry-run)."""
        return jax.eval_shape(lambda k: self.init(k), jax.random.key(0))

    # -- embedding of modality inputs -------------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        parts = []
        if cfg.frontend == "vision":
            img = batch["image_embeds"].astype(self.flags.jdtype)
            parts.append(jnp.einsum("bfd,de->bfe", img, params["frontend"]["proj"]))
            parts.append(embed(params["embed"], batch["tokens"]))
        elif cfg.frontend == "audio":
            frames = batch["frame_embeds"].astype(self.flags.jdtype)
            parts.append(jnp.einsum("bfd,de->bfe", frames, params["frontend"]["proj"]))
        else:
            parts.append(embed(params["embed"], batch["tokens"]))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        if self.policy is not None:
            x = self.policy.constrain_residual(x)
        return x

    def _logits(self, params, hidden):
        h = rmsnorm(params["final_norm"], hidden, self.cfg.norm_eps)
        w = params["embed"]["table"].T if self.cfg.tie_embeddings else params["head"]["w"]
        logits = jnp.einsum("...d,dv->...v", h, w)
        if self.policy is not None:
            logits = self.policy.constrain_logits(logits)
        return logits

    # -- train forward -----------------------------------------------------------
    def loss_fn(self, params, batch):
        """batch: tokens/labels (+ frontend embeds).  Returns (loss, metrics)."""
        x = self._embed_inputs(params, batch)
        hidden, aux, _ = transformer.forward_full(
            params, x, self.cfg, self.flags, self.policy, want_cache=False)
        labels = batch["labels"]
        mask = (labels >= 0)
        labels = jnp.maximum(labels, 0)
        nchunks = self.flags.loss_chunks
        if nchunks > 1:
            b, s, _ = hidden.shape
            assert s % nchunks == 0
            hs = hidden.reshape(b, nchunks, s // nchunks, -1).swapaxes(0, 1)
            ls = labels.reshape(b, nchunks, s // nchunks).swapaxes(0, 1)
            ms = mask.reshape(b, nchunks, s // nchunks).swapaxes(0, 1)

            def chunk_loss(carry, inp):
                h, l, m = inp
                lg = self._logits(params, h).astype(jnp.float32)
                lz = jax.scipy.special.logsumexp(lg, axis=-1)
                gold = jnp.take_along_axis(lg, l[..., None], axis=-1)[..., 0]
                return carry + jnp.sum((lz - gold) * m), None

            total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                                    (hs, ls, ms), unroll=self.flags.unroll)
            ce = total / jnp.maximum(jnp.sum(mask), 1)
        else:
            logits = self._logits(params, hidden).astype(jnp.float32)
            lz = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
            ce = jnp.sum((lz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # -- prefill / decode ----------------------------------------------------------
    def prefill(self, params, batch):
        """Returns (last-position logits (B, V), caches)."""
        x = self._embed_inputs(params, batch)
        hidden, _, caches = transformer.forward_full(
            params, x, self.cfg,
            dataclasses.replace(self.flags, remat="none"),
            self.policy, want_cache=True)
        logits = self._logits(params, hidden[:, -1:, :])[:, 0]
        return logits, caches

    def decode_step(self, params, tokens, caches, pos):
        """tokens: (B, 1) int32; pos: scalar.  Returns (logits (B, V), caches)."""
        x = embed(params["embed"], tokens)
        hidden, caches = transformer.forward_decode(params, x, caches, pos,
                                                    self.cfg, unroll=self.flags.unroll)
        return self._logits(params, hidden)[:, 0], caches

    def empty_caches(self, batch, seq_len):
        return transformer.empty_caches(self.cfg, batch, seq_len, self.flags.jdtype)

    # -- input specs (dry-run stand-ins) ----------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = self.flags.jdtype
        if shape.kind in ("train", "prefill"):
            batch: Dict[str, Any] = {}
            if cfg.frontend == "vision":
                f = cfg.n_frontend_tokens
                batch["image_embeds"] = jax.ShapeDtypeStruct((b, f, cfg.d_model), dt)
                batch["tokens"] = jax.ShapeDtypeStruct((b, s - f), i32)
            elif cfg.frontend == "audio":
                batch["frame_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            else:
                batch["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            return batch
        # decode: one new token against a seq_len cache
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}


# ---------------------------------------------------------------------------
# Analytic parameter counts (for 6ND model-FLOPs accounting)
# ---------------------------------------------------------------------------


def count_params_analytic(cfg: ArchConfig, active_only: bool = False) -> int:
    d, dh = cfg.d_model, cfg.d_head
    total = 0
    for spec in cfg.layer_specs():
        if spec.mixer in ("attn", "attn_local"):
            total += d * cfg.n_heads * dh * 2 + d * cfg.n_kv_heads * dh * 2 + d
        else:
            di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
            total += d * (2 * di + 2 * n + h)          # in_proj
            total += (di + 2 * n) * (cfg.ssm_conv + 1)  # conv w+b
            total += 3 * h + di                        # A_log, D, dt_bias, norm
            total += di * d + d                        # out_proj + norm
        if spec.ffn == "dense":
            f = cfg.d_ff if cfg.d_ff else cfg.moe_d_ff
            total += 3 * d * f + d
        elif spec.ffn == "moe":
            e = cfg.moe_top_k if active_only else cfg.n_experts
            total += 3 * d * cfg.moe_d_ff * e
            total += d * cfg.n_experts                 # router
            total += 3 * d * cfg.moe_d_ff * cfg.n_shared_experts
            total += d
    total += d  # final norm
    if cfg.frontend:
        total += d * d
    # lm head participates in the matmul FLOPs; vocab embedding lookup does not
    total += d * cfg.vocab_size
    return total
