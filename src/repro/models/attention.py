"""GQA attention: full-sequence (train/prefill) and single-token decode paths.

The full-sequence path can run through either the XLA einsum implementation or
the Pallas flash-attention kernel (``repro.kernels``).  The XLA path is the
default when lowering for the CPU-hosted dry-run (Mosaic kernels only lower on
real TPU backends); kernel correctness is validated in interpret mode by the
test suite, and the roofline model accounts for the kernel's VMEM tiling.

Sharding design (see DESIGN.md §6): K/V heads are never repeated — GQA is a
grouped einsum over a (hkv, rep) split of the q heads, so the partitioner
never sees a broadcast that breaks propagation.  With SP the attention is
*sequence-sharded*: q stays seq-sharded on the model axis and the (small,
GQA) K/V are gathered — balanced for any head count, and the same
parallelisation the Pallas kernel's grid uses on real TPUs.  Decode attention
runs against a sequence-sharded KV cache (split-K/flash-decode): per-shard
partial softmax statistics are combined by XLA with scalar-sized collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, apply_rope, rmsnorm, rmsnorm_init


def attn_init(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "norm": rmsnorm_init(d, dtype),
        "wq": _normal(k1, (d, h, dh), dtype),
        "wk": _normal(k2, (d, hkv, dh), dtype),
        "wv": _normal(k3, (d, hkv, dh), dtype),
        "wo": _normal(k4, (h, dh, d), dtype),
    }


def _gqa_attend(q, k, v, scale, mask):
    """Grouped attention without materialising repeated K/V heads.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hkv, dh); mask: (Sq, Skv) bool.
    Returns (B, Sq, H, dh).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    q5 = q.reshape(b, sq, hkv, rep, dh)
    logits = jnp.einsum("bqkrd,bskd->bkrqs", q5, k).astype(jnp.float32) * scale
    if mask.ndim == 2:                   # (Sq, Skv) shared mask
        mask = mask[None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p, v)
    return o.reshape(b, sq, h, dh)


def full_attention(params, x, cfg, *, window=0, positions=None, impl="xla",
                   attn_block_q=256, attn_block_kv=256, policy=None):
    """Causal (optionally sliding-window) self attention over the whole seq.

    x: (B, S, D) -> (out (B, S, D), cache {k, v}: (B, S, Hkv, dh))
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    cache = {"k": k, "v": v}
    if policy is not None:
        q = policy.constrain_attn_q(q)
        k = policy.constrain_attn_kv(k)
        v = policy.constrain_attn_kv(v)

    if impl == "flash":
        from repro.kernels import ops

        o = ops.flash_attention(
            q, k, v, causal=True, window=window,
            block_q=attn_block_q, block_kv=attn_block_kv,
        )
    else:
        idx_q = jnp.arange(s)[:, None]
        idx_k = jnp.arange(s)[None, :]
        mask = idx_k <= idx_q
        if window:
            mask &= (idx_q - idx_k) < window
        o = _gqa_attend(q, k, v, cfg.d_head ** -0.5, mask)
    out = jnp.einsum("bqhk,hkd->bqd", o, params["wo"])
    return out, cache


def decode_attention(params, x, cache, pos, cfg, *, window=0):
    """One-token decode against a (B, S_max, Hkv, dh) cache.

    x: (B, 1, D); pos: scalar int32 (aligned batch decode).
    Returns (out (B, 1, D), updated cache).
    """
    b = x.shape[0]
    s_max = cache["k"].shape[1]
    per_slot = jnp.ndim(pos) > 0        # (B,) positions: continuous batching
    h = rmsnorm(params["norm"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", h, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", h, params["wv"])
    posb = jnp.broadcast_to(jnp.reshape(jnp.asarray(pos), (-1, 1)), (b, 1))
    q = apply_rope(q, posb, cfg.rope_theta)
    k_new = apply_rope(k_new, posb, cfg.rope_theta)

    if per_slot:
        # rows write at their own positions: one-hot masked blend (the
        # aligned fast path below keeps the cheap dynamic_update_slice)
        onehot = (jnp.arange(s_max)[None, :] == posb)[..., None, None]
        k_cache = jnp.where(onehot, k_new.astype(cache["k"].dtype), cache["k"])
        v_cache = jnp.where(onehot, v_new.astype(cache["v"].dtype), cache["v"])
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    cache = {"k": k_cache, "v": v_cache}

    idx = jnp.arange(s_max)[None, :]
    mask = idx <= posb                   # (B, S): per-row causal frontier
    if window:
        mask &= (posb - idx) < window
    mask = mask[:, None, None, None, :]  # (B, 1, 1, 1, S) over (b,k,r,q,s)
    o = _gqa_attend(q, k_cache, v_cache, cfg.d_head ** -0.5, mask)
    out = jnp.einsum("bqhk,hkd->bqd", o, params["wo"])
    return out, cache


def empty_cache(cfg, batch, seq_len, dtype):
    shp = (batch, seq_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
