from repro.models.model import BuildFlags, Model, count_params_analytic
