"""Mamba-2 SSD (state-space duality) mixer block.

TPU adaptation (DESIGN.md §2): the chunked SSD formulation is used because the
intra-chunk term is a dense masked matmul (MXU-friendly) and the inter-chunk
recurrence is a short scan over S/chunk states — unlike the Mamba-1 selective
scan, which is a length-S sequential elementwise recurrence that maps poorly
onto systolic hardware.  The intra-chunk compute is also provided as a Pallas
kernel (``repro.kernels.ssd_scan``); this module is the pure-jnp path and the
oracle the kernel is tested against.

Sharding co-design (§Perf C): the reference Mamba-2 fuses [z | x | B | C | dt]
into one ``in_proj`` and one grouped conv.  Slicing that fused output at
offsets that don't align with tensor-parallel shards forces XLA to all-gather
the full activation every layer (measured: 94 GiB/device/step on mamba2-780m
train_4k).  We therefore keep **separate projections per segment** (wz, wx,
wb, wc, wdt) and **separate depthwise convs** (mathematically identical to
the fused grouped conv), so every segment is independently TP-sharded and no
resharding slice ever appears.

Single group (G=1) of B/C heads, as in the released mamba2 configs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _normal, gated_rmsnorm, rmsnorm, rmsnorm_init


def mamba_init(key, cfg, dtype):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 7)
    return {
        "norm": rmsnorm_init(d, dtype),
        "wz": _normal(ks[0], (d, di), dtype),
        "wx": _normal(ks[1], (d, di), dtype),
        "wb": _normal(ks[2], (d, n), dtype),
        "wc": _normal(ks[3], (d, n), dtype),
        "wdt": _normal(ks[4], (d, h), dtype),
        "conv_x": _normal(ks[5], (di, cfg.ssm_conv), dtype, scale=0.1),
        "conv_b": _normal(ks[6], (n, cfg.ssm_conv), dtype, scale=0.1),
        "conv_c": _normal(ks[0], (n, cfg.ssm_conv), dtype, scale=0.1),
        "bias_x": jnp.zeros((di,), dtype),
        "bias_b": jnp.zeros((n,), dtype),
        "bias_c": jnp.zeros((n,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gated_norm": rmsnorm_init(di, dtype),
        "out_proj": _normal(ks[1], (di, d), dtype),
    }


def _causal_conv(w, bias, x):
    """Depthwise causal conv.  x: (B, S, C); w: (C, K)."""
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(k):  # K is 4: unrolled shifts beat a conv op on TPU here
        out = out + pad[:, j : j + x.shape[1], :] * w[None, None, :, j]
    return out + bias[None, None, :]


def ssd_chunked(x, a_log, b, c, dt, chunk, state_init=None, impl="jnp",
                unroll=False):
    """Chunked SSD scan.

    x: (B, S, H, P) head inputs;  a_log: (B, S, H) = dt*A (negative);
    b, c: (B, S, N);  dt: (B, S, H);  returns (y (B,S,H,P), state (B,H,P,N)).
    """
    if impl == "pallas":
        from repro.kernels import ops

        return ops.ssd_scan(x, a_log, b, c, dt, chunk=chunk)

    bsz, s, h, p = x.shape
    n = b.shape[-1]
    if s % chunk:
        # pad to a chunk multiple: dt=0 kills padded inputs, a_log=0 keeps the
        # state frozen through the pad, padded outputs are sliced off below.
        pad = chunk - s % chunk
        padf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        y, state = ssd_chunked(padf(x), padf(a_log), padf(b), padf(c),
                               padf(dt), chunk, state_init=state_init,
                               impl=impl, unroll=unroll)
        return y[:, :s], state
    nc, q = s // chunk, chunk

    # reshape to (nc, B, Q, ...) for scan over chunks
    def chunked(t):
        return t.reshape(bsz, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xc, ac, bc_, cc, dtc = map(chunked, (x, a_log, b, c, dt))
    if state_init is None:
        state_init = jnp.zeros((bsz, h, p, n), jnp.float32)

    def body(state, inp):
        xq, aq, bq, cq, dtq = inp            # (B,Q,H,P), (B,Q,H), (B,Q,N)...
        aq = aq.astype(jnp.float32)
        cum = jnp.cumsum(aq, axis=1)                        # (B,Q,H)
        # intra-chunk: S[i,j] = (c_i . b_j) * exp(cum_i - cum_j) * dt_j,  j <= i
        cb = jnp.einsum("bin,bjn->bij", cq.astype(jnp.float32), bq.astype(jnp.float32))
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,i,j,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        sm = cb[..., None] * decay * dtq[:, None, :, :]
        sm = jnp.where(mask[None, :, :, None], sm, 0.0)
        y = jnp.einsum("bijh,bjhp->bihp", sm, xq.astype(jnp.float32))
        # inter-chunk: contribution of the incoming state
        state_decay = jnp.exp(cum)                          # (B,Q,H)
        y += jnp.einsum("bin,bhpn,bih->bihp", cq.astype(jnp.float32), state, state_decay)
        # state update
        total = cum[:, -1]                                  # (B,H)
        rem = jnp.exp(total[:, None] - cum)                 # (B,Q,H)
        dx = xq.astype(jnp.float32) * (dtq * rem)[..., None]  # (B,Q,H,P)
        new_state = state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bqhp,bqn->bhpn", dx, bq.astype(jnp.float32)
        )
        return new_state, y.astype(x.dtype)

    state, ys = jax.lax.scan(body, state_init, (xc, ac, bc_, cc, dtc),
                             unroll=unroll)
    y = ys.swapaxes(0, 1).reshape(bsz, s, h, p)
    return y, state


def _projections(params, hh):
    z = jnp.einsum("bsd,de->bse", hh, params["wz"])
    xs_raw = jnp.einsum("bsd,de->bse", hh, params["wx"])
    b_raw = jnp.einsum("bsd,de->bse", hh, params["wb"])
    c_raw = jnp.einsum("bsd,de->bse", hh, params["wc"])
    dt_raw = jnp.einsum("bsd,de->bse", hh, params["wdt"])
    return z, xs_raw, b_raw, c_raw, dt_raw


def mamba_block(params, x, cfg, impl="jnp", unroll=False):
    """Full-sequence Mamba-2 block.  x: (B,S,D) -> (out, cache)."""
    bsz, s, _ = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    hh = rmsnorm(params["norm"], x, cfg.norm_eps)
    z, xs_raw, b_raw, c_raw, dt_raw = _projections(params, hh)
    xs = jax.nn.silu(_causal_conv(params["conv_x"], params["bias_x"], xs_raw))
    b = jax.nn.silu(_causal_conv(params["conv_b"], params["bias_b"], b_raw))
    c = jax.nn.silu(_causal_conv(params["conv_c"], params["bias_c"], c_raw))
    xs = xs.reshape(bsz, s, h, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])                        # (H,)
    a_log = dt * a[None, None, :]
    y, state = ssd_chunked(xs, a_log, b, c, dt, cfg.ssm_chunk, impl=impl,
                           unroll=unroll)
    y = y + xs * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, di)
    y = gated_rmsnorm(params["gated_norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    # decode cache: last (ssm_conv-1) pre-conv segment values + final state
    km1 = cfg.ssm_conv - 1
    conv_cache = jnp.concatenate(
        [xs_raw[:, -km1:], b_raw[:, -km1:], c_raw[:, -km1:]], axis=-1)
    return out, {"state": state, "conv": conv_cache}


def mamba_decode(params, x, cache, cfg):
    """One-token decode.  x: (B,1,D); cache {state (B,H,P,N), conv (B,K-1,C)}."""
    bsz = x.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    hh = rmsnorm(params["norm"], x, cfg.norm_eps)
    z, xs_raw, b_raw, c_raw, dt_raw = _projections(params, hh)
    new_seg = jnp.concatenate([xs_raw, b_raw, c_raw], axis=-1)   # (B,1,C)
    window = jnp.concatenate([cache["conv"], new_seg], axis=1)   # (B,K,C)

    def seg_conv(w, bias, lo, hi):
        return jax.nn.silu(
            jnp.einsum("bkc,ck->bc", window[:, :, lo:hi], w) + bias)

    xs = seg_conv(params["conv_x"], params["bias_x"], 0, di)
    b = seg_conv(params["conv_b"], params["bias_b"], di, di + n)
    c = seg_conv(params["conv_c"], params["bias_c"], di + n, di + 2 * n)
    xs = xs.reshape(bsz, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * a[None, :])                             # (B,H)
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", xs, b.astype(jnp.float32), dt)
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32))
    y = y + xs * params["D"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = gated_rmsnorm(params["gated_norm"], y, z, cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    new_conv = jnp.concatenate([cache["conv"][:, 1:], new_seg], axis=1)
    return out, {"state": state, "conv": new_conv}


def empty_mamba_cache(cfg, batch):
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.n_ssm_heads, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), jnp.float32),
    }
