"""GLM-4 9B — dense GQA (kv=2) + RoPE. [hf:THUDM/glm-4-9b; hf]"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    pattern=(LayerSpec(),),
))
