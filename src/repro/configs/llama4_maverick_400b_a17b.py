"""Llama-4 Maverick 400B-A17B — MoE 128e top-1 (+1 shared), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Maverick interleaves dense and MoE FFN layers 1:1 (that is what makes the
total 400B rather than ~780B at 48 layers × 128 experts)."""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=128,
    n_shared_experts=1,
    moe_top_k=1,
    moe_d_ff=8192,
    rope_theta=500_000.0,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),
             LayerSpec(mixer="attn", ffn="moe")),
))
