"""Architecture & workload-shape definitions.

``ArchConfig`` is the single source of truth a model is built from.  Every
assigned architecture (plus the paper's own two workloads) provides one
``ArchConfig`` in its ``src/repro/configs/<id>.py`` module and registers it.

Layer heterogeneity (gemma3's 5:1 local:global, jamba's 1:7 attn:mamba with
every-other-layer MoE) is expressed with a repeating ``pattern`` of
``LayerSpec``s.  The transformer stack scans over ``len(layers)//len(pattern)``
pattern groups and unrolls the remainder, so HLO size stays O(pattern), not
O(depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

MIXER_ATTN = "attn"            # full (causal) self attention
MIXER_ATTN_LOCAL = "attn_local"  # sliding-window self attention
MIXER_MAMBA = "mamba"          # Mamba-2 SSD block

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"              # e.g. mamba2 blocks carry no separate FFN


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = MIXER_ATTN
    ffn: str = FFN_DENSE

    def __post_init__(self):
        assert self.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL, MIXER_MAMBA), self.mixer
        assert self.ffn in (FFN_DENSE, FFN_MOE, FFN_NONE), self.ffn


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    # -- attention ----------------------------------------------------------
    n_heads: int = 0                  # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0                 # explicit; may differ from d_model//n_heads
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # window size for MIXER_ATTN_LOCAL layers
    # -- dense FFN -----------------------------------------------------------
    d_ff: int = 0
    # -- MoE ------------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden (fine-grained MoE)
    capacity_factor: float = 1.25
    first_k_dense: int = 0            # leading layers that use dense FFN instead
    # -- Mamba-2 SSD -----------------------------------------------------------
    ssm_state: int = 0                # N
    ssm_head_dim: int = 64            # P
    ssm_expand: int = 2               # d_inner = expand * d_model
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # -- layer pattern ----------------------------------------------------------
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    # -- modality frontend (stub: precomputed embeddings are model inputs) -----
    frontend: Optional[str] = None    # None | "vision" | "audio"
    n_frontend_tokens: int = 0        # e.g. 576 image-patch tokens
    # -- misc -------------------------------------------------------------------
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # Whether the arch has a sub-quadratic long-context path (long_500k runs).
    subquadratic: bool = False

    # -- derived ----------------------------------------------------------------
    @property
    def d_head(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Expanded per-layer specs of length ``n_layers``.

        ``first_k_dense`` downgrades the MoE FFN of the leading layers to dense
        (DeepSeek-MoE convention).
        """
        reps = -(-self.n_layers // len(self.pattern))
        specs = (self.pattern * reps)[: self.n_layers]
        out = []
        for i, s in enumerate(specs):
            if s.ffn == FFN_MOE and i < self.first_k_dense:
                s = LayerSpec(mixer=s.mixer, ffn=FFN_DENSE)
            out.append(s)
        return tuple(out)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs accounting)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


# ---------------------------------------------------------------------------
# Workload shapes (assigned set — identical for every LM-family arch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str           # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runs?, reason).  long_500k needs a sub-quadratic long-context path."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch: long_500k skipped (per assignment)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, f"duplicate arch {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:  # lazy import of all config modules
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    import importlib

    mods = [
        "deepseek_moe_16b",
        "llama4_maverick_400b_a17b",
        "glm4_9b",
        "tinyllama_1_1b",
        "gemma3_27b",
        "yi_9b",
        "jamba_v0_1_52b",
        "musicgen_medium",
        "internvl2_2b",
        "mamba2_780m",
        "llama2_7b",
        "llava_v1_5_7b",
    ]
    for m in mods:
        importlib.import_module(f"repro.configs.{m}")


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=max(2, len(cfg.pattern)),
        d_model=64,
        vocab_size=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        n_experts=4 if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        first_k_dense=min(cfg.first_k_dense, 1),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        sliding_window=8 if cfg.sliding_window else 0,
        n_frontend_tokens=4 if cfg.n_frontend_tokens else 0,
        name=cfg.name + "-reduced",
    )
    base.update(overrides)
    # keep pattern length dividing n_layers where possible
    if base["n_layers"] % len(cfg.pattern):
        base["n_layers"] = len(cfg.pattern) * max(1, base["n_layers"] // len(cfg.pattern))
        base["n_layers"] = max(base["n_layers"], len(cfg.pattern))
    return dataclasses.replace(cfg, **base)
