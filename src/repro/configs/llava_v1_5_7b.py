"""LLaVA-v1.5 7B — the paper's own second workload (Fig. 4): Vicuna-7B
backbone (llama2-7b arch) + CLIP ViT-L/14-336 frontend (STUB, 576 patch
tokens). [NeurIPS 2023 Visual Instruction Tuning]"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="llava-v1.5-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    frontend="vision",
    n_frontend_tokens=576,
    pattern=(LayerSpec(),),
))
