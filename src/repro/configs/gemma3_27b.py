"""Gemma-3 27B — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

The 5 sliding-window layers per group make long-context decode sub-quadratic
in aggregate; the 1-in-6 global layers are linear-per-token at decode, so
long_500k runs for this arch (noted in DESIGN.md §Arch-applicability)."""
from repro.configs.base import ArchConfig, LayerSpec, register

_L = LayerSpec(mixer="attn_local")
_G = LayerSpec(mixer="attn")

CONFIG = register(ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    pattern=(_L, _L, _L, _L, _L, _G),
    subquadratic=True,
))
