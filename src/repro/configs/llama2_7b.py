"""Llama-2 7B — the paper's own first workload (Fig. 2). [arXiv:2302.13971]"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    pattern=(LayerSpec(),),
))
