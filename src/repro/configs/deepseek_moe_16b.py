"""DeepSeek-MoE 16B — fine-grained MoE, 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,          # dense FFN width used by the first_k_dense layer
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,       # fine-grained expert hidden
    first_k_dense=1,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
))
