"""InternVL2 2B — InternViT frontend (STUB) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (n_frontend_tokens of them) that are concatenated
ahead of the text embeddings."""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    n_frontend_tokens=576,
    pattern=(LayerSpec(),),
))
