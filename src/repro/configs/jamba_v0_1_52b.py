"""Jamba v0.1 52B — Mamba+attention 1:7 interleave, MoE 16e top-2 every other
layer. [arXiv:2403.19887; hf]

Hardware adaptation note (DESIGN.md §2): Jamba uses Mamba-1 selective scan on
GPU; we implement the state-space mixer with the Mamba-2 SSD chunked matmul
formulation because it maps onto the TPU MXU (dense chunk matmuls) instead of
a sequential elementwise scan."""
from repro.configs.base import ArchConfig, LayerSpec, register

_pat = []
for i in range(8):
    mixer = "attn" if i == 4 else "mamba"       # attention every 8th layer
    ffn = "moe" if i % 2 == 1 else "dense"      # MoE every other layer
    _pat.append(LayerSpec(mixer=mixer, ffn=ffn))

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    pattern=tuple(_pat),
    subquadratic=True,
))
