"""MusicGen medium — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings; the backbone is the transformer below."""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio",
    pattern=(LayerSpec(),),
))
