from repro.configs.base import (
    ArchConfig,
    LayerSpec,
    ShapeConfig,
    SHAPES,
    get_arch,
    list_archs,
    reduced,
    shape_applicable,
)

ASSIGNED_ARCHS = [
    "deepseek-moe-16b",
    "llama4-maverick-400b-a17b",
    "glm4-9b",
    "tinyllama-1.1b",
    "gemma3-27b",
    "yi-9b",
    "jamba-v0.1-52b",
    "musicgen-medium",
    "internvl2-2b",
    "mamba2-780m",
]
PAPER_ARCHS = ["llama2-7b", "llava-v1.5-7b"]
