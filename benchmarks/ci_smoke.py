"""CI regression gate for the pipelined dispatch path.

Runs a small exploration (50 configs by default) through the full
JHost/DispatchScheduler loop over loopback — the pipelined and eager paths
back-to-back per rep — checks every config completed ok, and fails (exit 1)
on regression beyond ``SMOKE_TOLERANCE`` (default 30%) vs the checked-in
baseline in ``benchmarks/smoke_baseline.json``.

    PYTHONPATH=src python -m benchmarks.ci_smoke

What is gated: the **median per-pair eager/pipelined wall ratio** — the
pipeline's advantage over the barrier on this machine, right now.  A
50-config exploration is a few ms of wall, so absolute evals/sec depends on
the runner's speed and load far more than on the code; the interleaved
ratio cancels that common mode, catching regressions that slow the
pipelined path specifically (the point of this subsystem) on any hardware.
Absolute evals/sec against the baseline is printed for the log, and becomes
the gate instead when ``SMOKE_BASELINE`` (evals/sec) is set explicitly.
A regression that slows both paths equally is caught by the absolute line
in the log, not by the ratio gate.

The baseline is recorded with the identical interleaved statistic:
``SMOKE_RECORD=1 python -m benchmarks.run evalpath`` refreshes
``benchmarks/smoke_baseline.json`` (explicit opt-in; ``results/`` is
gitignored, so CI checkouts only see the benchmarks/ file).

Env knobs: SMOKE_SAMPLES (default 50), SMOKE_TOLERANCE (default 0.30),
SMOKE_BASELINE (absolute evals/sec gate override).
"""
import json
import os
import sys

from benchmarks.common import REPO, evalpath_workload, smoke_measure

N = int(os.environ.get("SMOKE_SAMPLES", "50"))
TOLERANCE = float(os.environ.get("SMOKE_TOLERANCE", "0.30"))
BASELINE_PATH = os.path.join(REPO, "benchmarks", "smoke_baseline.json")


def main() -> int:
    import numpy as np

    from repro.core import TestConfig

    space, jc, build = evalpath_workload()
    rng = np.random.default_rng(0)
    tcs = [TestConfig(i, "toy", "generate", space.sample(rng))
           for i in range(N)]
    wall_p, wall_e, ratio, recs = smoke_measure(tcs, jc, build)
    bad = [cid for cid, r in recs.items() if r.status != "ok"]
    if len(recs) != N or bad:
        print(f"SMOKE FAIL: {len(recs)}/{N} configs, non-ok: {bad[:5]}")
        return 1
    eps = N / wall_p
    print(f"smoke: {eps:.0f} pipelined evals/s over {N} configs "
          f"({N / wall_e:.0f} eager; pipelined/eager ratio {ratio:.2f})")

    override = os.environ.get("SMOKE_BASELINE")
    if override is not None:        # explicit absolute gate
        floor = float(override) * (1.0 - TOLERANCE)
        verdict = "ok" if eps >= floor else "REGRESSION"
        print(f"smoke: absolute gate {eps:.0f} vs floor {floor:.0f} "
              f"(SMOKE_BASELINE={override}, tolerance {TOLERANCE:.0%}) "
              f"-> {verdict}")
        return 0 if eps >= floor else 1

    try:
        with open(BASELINE_PATH) as f:
            baseline = json.load(f)
        base_ratio = float(baseline["pipelined_vs_eager_ratio"])
        base_eps = float(baseline["pipelined_smoke_evals_per_s"])
    except (OSError, KeyError, ValueError, json.JSONDecodeError):
        print("smoke: no checked-in baseline — passing (SMOKE_RECORD=1 "
              "benchmarks.run evalpath records one)")
        return 0

    print(f"smoke: absolute {eps:.0f} vs {base_eps:.0f} baseline evals/s "
          f"({eps / base_eps:.2f}x; informational — hardware-dependent)")
    floor = base_ratio * (1.0 - TOLERANCE)
    verdict = "ok" if ratio >= floor else "REGRESSION"
    print(f"smoke: ratio gate {ratio:.2f} vs floor {floor:.2f} "
          f"(baseline ratio {base_ratio:.2f}, tolerance {TOLERANCE:.0%}) "
          f"-> {verdict}")
    return 0 if ratio >= floor else 1


if __name__ == "__main__":
    sys.exit(main())
