"""CI regression gate for the pipelined dispatch path.

Runs a small exploration (50 configs by default) through the full
JHost/DispatchScheduler loop over loopback — the pipelined and eager paths
back-to-back per rep — checks every config completed ok, and fails (exit 1)
on regression beyond ``SMOKE_TOLERANCE`` (default 30%) vs the checked-in
baseline in ``benchmarks/smoke_baseline.json``.

    PYTHONPATH=src python -m benchmarks.ci_smoke

What is gated: the **median per-pair eager/pipelined wall ratio** — the
pipeline's advantage over the barrier on this machine, right now.  A
50-config exploration is a few ms of wall, so absolute evals/sec depends on
the runner's speed and load far more than on the code; the interleaved
ratio cancels that common mode, catching regressions that slow the
pipelined path specifically (the point of this subsystem) on any hardware.
Absolute evals/sec against the baseline is printed for the log, and becomes
the gate instead when ``SMOKE_BASELINE`` (evals/sec) is set explicitly.
A regression that slows both paths equally is caught by the absolute line
in the log, not by the ratio gate.

The baseline is recorded with the identical interleaved statistic:
``SMOKE_RECORD=1 python -m benchmarks.run evalpath`` refreshes
``benchmarks/smoke_baseline.json`` (explicit opt-in; ``results/`` is
gitignored, so CI checkouts only see the benchmarks/ file).

A second row gates the **searchpath** (PR 3's tentpole): the same
50-config exploration driven by a live BayesOpt(EHVI) searcher, run
async+incremental and pre-PR-inline back-to-back per rep, gated on the
median per-pair pre-PR/async wall ratio vs
``searchpath_prepr_vs_async_ratio`` in the same baseline file
(recorded by ``SMOKE_RECORD=1 benchmarks.run searchpath``).

A third row gates the **fleetpath** (PR 4's tentpole): a fixed 50-config
compile-dominated scenario (8 sw fingerprints, 5 ms injected compile,
4 clients — see ``fleetpath_smoke_workload``), run with strict compile-
affinity placement and with affinity off back-to-back per rep, gated on
the median per-pair rr/affinity wall ratio vs
``fleetpath_rr_vs_affinity_ratio`` (recorded by ``SMOKE_RECORD=1
benchmarks.run fleetpath``).  No persistent cache is involved, so every
rep pays identical cold compiles and the ratio isolates placement.

A fourth row gates the **fleet store** (PR 7's tentpole): the same
fleetpath smoke scenario run cold (fresh clients populate a serve-mode
``FleetArtifactStore``) then warm-peer (brand-new clients, same store —
every artifact arrives over the wire, zero compiles), gated on the
median per-pair cold/warm-peer wall ratio vs
``fleet_store_cold_vs_warmpeer_ratio`` (recorded by ``SMOKE_RECORD=1
benchmarks.run fleetpath``).  A warm-peer run that compiles at all is a
hard fail regardless of baseline.

A fifth row gates the **big-n jax search path** (PR 6's tentpole): the
per-cycle (tell+ask) cost ratio between two observation-count checkpoints
both past the subset-of-data inducing threshold
(``searchpath_bign_smoke_measure``: checkpoints 300/1200, inducing 256).
The ratio gets a hard 2.0 cap (the flat-latency acceptance number) plus
the usual tolerance check vs ``searchpath_bign_smoke_flat_ratio`` in the
baseline (lower is better, so the gate is a ceiling).  When jax is not
importable the gate prints a note and passes — the numpy path is the
reference and CI must stay green without the accelerator stack.

Env knobs: SMOKE_SAMPLES (default 50), SMOKE_TOLERANCE (default 0.30),
SMOKE_BASELINE (absolute evals/sec gate override for the evalpath row).
"""
import json
import os
import sys

from benchmarks.common import (REPO, evalpath_workload,
                               fleet_store_smoke_measure,
                               fleetpath_smoke_measure,
                               fleetpath_smoke_workload,
                               searchpath_bign_smoke_measure,
                               searchpath_smoke_measure, smoke_measure)

N = int(os.environ.get("SMOKE_SAMPLES", "50"))
TOLERANCE = float(os.environ.get("SMOKE_TOLERANCE", "0.30"))
BASELINE_PATH = os.path.join(REPO, "benchmarks", "smoke_baseline.json")


def _load_baseline() -> dict:
    try:
        with open(BASELINE_PATH) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def evalpath_gate(space, jc, build, baseline) -> int:
    import numpy as np

    from repro.core import TestConfig

    rng = np.random.default_rng(0)
    tcs = [TestConfig(i, "toy", "generate", space.sample(rng))
           for i in range(N)]
    wall_p, wall_e, ratio, recs = smoke_measure(tcs, jc, build)
    bad = [cid for cid, r in recs.items() if r.status != "ok"]
    if len(recs) != N or bad:
        print(f"SMOKE FAIL: {len(recs)}/{N} configs, non-ok: {bad[:5]}")
        return 1
    eps = N / wall_p
    print(f"smoke: {eps:.0f} pipelined evals/s over {N} configs "
          f"({N / wall_e:.0f} eager; pipelined/eager ratio {ratio:.2f})")

    override = os.environ.get("SMOKE_BASELINE")
    if override is not None:        # explicit absolute gate
        floor = float(override) * (1.0 - TOLERANCE)
        verdict = "ok" if eps >= floor else "REGRESSION"
        print(f"smoke: absolute gate {eps:.0f} vs floor {floor:.0f} "
              f"(SMOKE_BASELINE={override}, tolerance {TOLERANCE:.0%}) "
              f"-> {verdict}")
        return 0 if eps >= floor else 1

    try:
        base_ratio = float(baseline["pipelined_vs_eager_ratio"])
        base_eps = float(baseline["pipelined_smoke_evals_per_s"])
    except (KeyError, ValueError):
        print("smoke: no checked-in evalpath baseline — passing "
              "(SMOKE_RECORD=1 benchmarks.run evalpath records one)")
        return 0

    print(f"smoke: absolute {eps:.0f} vs {base_eps:.0f} baseline evals/s "
          f"({eps / base_eps:.2f}x; informational — hardware-dependent)")
    floor = base_ratio * (1.0 - TOLERANCE)
    verdict = "ok" if ratio >= floor else "REGRESSION"
    print(f"smoke: ratio gate {ratio:.2f} vs floor {floor:.2f} "
          f"(baseline ratio {base_ratio:.2f}, tolerance {TOLERANCE:.0%}) "
          f"-> {verdict}")
    return 0 if ratio >= floor else 1


def searchpath_gate(space, jc, build, baseline) -> int:
    wall_a, wall_p, ratio, store = searchpath_smoke_measure(
        N, space, jc, build)
    bad = [r.config_id for r in store.records if r.status != "ok"]
    if len(store.records) != N or bad:
        print(f"SMOKE FAIL (searchpath): {len(store.records)}/{N} configs, "
              f"non-ok: {bad[:5]}")
        return 1
    eps = N / wall_a
    print(f"smoke: {eps:.0f} async-searchpath evals/s over {N} configs "
          f"({N / wall_p:.0f} pre-PR inline; pre-PR/async ratio {ratio:.2f})")

    try:
        base_ratio = float(baseline["searchpath_prepr_vs_async_ratio"])
        base_eps = float(baseline["searchpath_async_smoke_evals_per_s"])
    except (KeyError, ValueError):
        print("smoke: no checked-in searchpath baseline — passing "
              "(SMOKE_RECORD=1 benchmarks.run searchpath records one)")
        return 0

    print(f"smoke: searchpath absolute {eps:.0f} vs {base_eps:.0f} baseline "
          f"evals/s ({eps / base_eps:.2f}x; informational)")
    floor = base_ratio * (1.0 - TOLERANCE)
    verdict = "ok" if ratio >= floor else "REGRESSION"
    print(f"smoke: searchpath ratio gate {ratio:.2f} vs floor {floor:.2f} "
          f"(baseline ratio {base_ratio:.2f}, tolerance {TOLERANCE:.0%}) "
          f"-> {verdict}")
    return 0 if ratio >= floor else 1


def fleetpath_gate(baseline) -> int:
    tcs, jc, build = fleetpath_smoke_workload()
    wall_a, wall_r, ratio, recs = fleetpath_smoke_measure(tcs, jc, build)
    n = len(tcs)
    bad = [cid for cid, r in recs.items() if r.status != "ok"]
    if len(recs) != n or bad:
        print(f"SMOKE FAIL (fleetpath): {len(recs)}/{n} configs, "
              f"non-ok: {bad[:5]}")
        return 1
    eps = n / wall_a
    print(f"smoke: {eps:.0f} affinity-fleetpath evals/s over {n} configs "
          f"({n / wall_r:.0f} round-robin; rr/affinity ratio {ratio:.2f})")

    try:
        base_ratio = float(baseline["fleetpath_rr_vs_affinity_ratio"])
        base_eps = float(baseline["fleetpath_affinity_smoke_evals_per_s"])
    except (KeyError, ValueError):
        print("smoke: no checked-in fleetpath baseline — passing "
              "(SMOKE_RECORD=1 benchmarks.run fleetpath records one)")
        return 0

    print(f"smoke: fleetpath absolute {eps:.0f} vs {base_eps:.0f} baseline "
          f"evals/s ({eps / base_eps:.2f}x; informational)")
    floor = base_ratio * (1.0 - TOLERANCE)
    verdict = "ok" if ratio >= floor else "REGRESSION"
    print(f"smoke: fleetpath ratio gate {ratio:.2f} vs floor {floor:.2f} "
          f"(baseline ratio {base_ratio:.2f}, tolerance {TOLERANCE:.0%}) "
          f"-> {verdict}")
    return 0 if ratio >= floor else 1


def fleet_store_gate(baseline) -> int:
    tcs, jc, build = fleetpath_smoke_workload()
    wall_c, wall_w, ratio, n_cold, n_warm = fleet_store_smoke_measure(
        tcs, jc, build)
    n = len(tcs)
    if n_warm != 0:
        print(f"SMOKE FAIL (fleet_store): warm-peer run compiled "
              f"{n_warm} times — every artifact should arrive over "
              f"the wire from the fleet store")
        return 1
    eps = n / wall_w
    print(f"smoke: {eps:.0f} warm-peer fleet-store evals/s over {n} configs "
          f"({n / wall_c:.0f} cold fleet, {n_cold} compiles; "
          f"cold/warm-peer ratio {ratio:.2f})")

    try:
        base_ratio = float(baseline["fleet_store_cold_vs_warmpeer_ratio"])
        base_eps = float(baseline["fleet_store_warmpeer_smoke_evals_per_s"])
    except (KeyError, ValueError):
        print("smoke: no checked-in fleet_store baseline — passing "
              "(SMOKE_RECORD=1 benchmarks.run fleetpath records one)")
        return 0

    print(f"smoke: fleet_store absolute {eps:.0f} vs {base_eps:.0f} baseline "
          f"evals/s ({eps / base_eps:.2f}x; informational)")
    floor = base_ratio * (1.0 - TOLERANCE)
    verdict = "ok" if ratio >= floor else "REGRESSION"
    print(f"smoke: fleet_store ratio gate {ratio:.2f} vs floor {floor:.2f} "
          f"(baseline ratio {base_ratio:.2f}, tolerance {TOLERANCE:.0%}) "
          f"-> {verdict}")
    return 0 if ratio >= floor else 1


def searchpath_bign_gate(baseline) -> int:
    try:
        from repro.core.search import gp_jax  # noqa: F401
    except Exception as e:
        print(f"smoke: big-n jax gate skipped — jax unavailable ({e})")
        return 0
    ratio = searchpath_bign_smoke_measure()
    print(f"smoke: big-n jax flat ratio {ratio:.2f} (tell+ask cost at "
          f"n=1200 vs n=300, inducing 256 — both past the threshold)")
    if ratio > 2.0:
        print(f"smoke: big-n hard cap FAIL — {ratio:.2f} > 2.0 (ask cost "
              f"is not flat past the inducing threshold)")
        return 1

    try:
        base = float(baseline["searchpath_bign_smoke_flat_ratio"])
    except (KeyError, ValueError):
        print("smoke: no checked-in big-n baseline — passing "
              "(SMOKE_RECORD=1 benchmarks.run searchpath records one)")
        return 0

    # a healthy flat path records a baseline near 1.0, where ±30% relative
    # is only ~0.3 absolute — too tight for a ms-scale ratio on a loaded
    # runner.  Floor the ceiling at 1.5: still far under the 2.0 cap, and
    # a regression back to unbounded growth blows past both.
    ceiling = max(base * (1.0 + TOLERANCE), 1.5)
    verdict = "ok" if ratio <= ceiling else "REGRESSION"
    print(f"smoke: big-n ratio gate {ratio:.2f} vs ceiling {ceiling:.2f} "
          f"(baseline ratio {base:.2f}, tolerance {TOLERANCE:.0%}; lower "
          f"is better) -> {verdict}")
    return 0 if ratio <= ceiling else 1


def main() -> int:
    space, jc, build = evalpath_workload()
    baseline = _load_baseline()
    rc = evalpath_gate(space, jc, build, baseline)
    rc_search = searchpath_gate(space, jc, build, baseline)
    rc_fleet = fleetpath_gate(baseline)
    rc_store = fleet_store_gate(baseline)
    rc_bign = searchpath_bign_gate(baseline)
    return rc or rc_search or rc_fleet or rc_store or rc_bign


if __name__ == "__main__":
    sys.exit(main())
