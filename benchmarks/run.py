import os as _os
_os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = mean wall time per
JClient evaluation; derived = the artifact's headline number).

    PYTHONPATH=src python -m benchmarks.run              # all
    PYTHONPATH=src python -m benchmarks.run fig2 table1  # subset
    BENCH_SAMPLES=50 ... to shrink the 200-config sweeps (CI use)
"""
import json
import os
import sys
import time

from benchmarks.common import (RESULTS, ask_cost_curve, bign_ask_curve,
                               evalpath_workload, explore_generation,
                               fleet_store_smoke_measure,
                               fleetpath_smoke_measure,
                               fleetpath_smoke_workload, fleetpath_workload,
                               jax_numpy_ehvi_equiv, record_smoke_baseline,
                               run_evalpath, run_fleetpath, run_hostpath,
                               run_searchpath, scatter_png,
                               searchpath_bign_smoke_measure,
                               searchpath_smoke_measure, smoke_measure,
                               sync_picks_identical)

N_SAMPLES = int(os.environ.get("BENCH_SAMPLES", "200"))


# ---------------------------------------------------------------------------
# Evaluation-path throughput: scalar vs batched DSE loop (PR 1 tentpole)
# ---------------------------------------------------------------------------


def bench_evalpath():
    """Evaluations/sec through the DSE loop, four ways over loopback.

    Same N configs through: scalar = one testConfig per message (the seed
    protocol), batched = one columnar frame direct to a JClient (PR 1's
    framing), eager = the full JHost/scheduler loop with barrier dispatch
    (PR 1's batched host path), pipelined = double-buffered dispatch +
    adaptive chunk sizing.  Metrics must be bit-identical per config across
    every path *and* across json/binary codecs; a jittered-latency
    multi-client scenario measures how much the pipeline hides network
    stalls.  derived = batched/scalar speedup (×), tracked since PR 1.
    """
    import numpy as np

    from repro.core import TestConfig

    space, jc, build = evalpath_workload()
    rng = np.random.default_rng(0)
    tcs = [TestConfig(i, "toy", "generate", space.sample(rng))
           for i in range(N_SAMPLES)]
    unique_sw = len({jc.cache_key(t) for t in tcs})

    wall_s, compiles_s, res_s = run_evalpath(tcs, jc, build, batched=False)
    wall_b, compiles_b, res_b = run_evalpath(tcs, jc, build, batched=True)

    for cid, r in res_s.items():
        if r["metrics"] != res_b[cid]["metrics"]:
            raise RuntimeError(f"scalar/batched metrics diverge for {cid}: "
                               f"{r['metrics']} != {res_b[cid]['metrics']}")

    # host-loop paths: eager barrier (PR 1) vs pipelined double-buffering,
    # each under both wire codecs; all four must match the scalar metrics
    batch = max(min(N_SAMPLES // 8, 25), 1)
    walls = {}
    for disp in ("eager", "pipelined"):
        for cdc in ("json", "binary"):
            wall, recs = run_hostpath(
                tcs, jc, build, dispatch=disp, codec=cdc, batch_size=batch,
                chunk_budget_ms=5.0 if disp == "pipelined" else None)
            walls[(disp, cdc)] = wall
            for cid, r in res_s.items():
                if r["metrics"] != recs[cid].metrics:
                    raise RuntimeError(
                        f"{disp}/{cdc} metrics diverge for {cid}")
    wall_e = min(walls[("eager", "json")], walls[("eager", "binary")])
    wall_p = min(walls[("pipelined", "json")], walls[("pipelined", "binary")])

    # multi-client fleet with per-client latency jitter: the pipelined path
    # overlaps the wire latency with client compute, the barrier cannot
    jbatch = max(min(N_SAMPLES // 16, 13), 1)
    jitter_kw = dict(clients=2, batch_size=jbatch, latency_s=0.004,
                     jitter_s=0.004, reps=2)
    wall_je, _ = run_hostpath(tcs, jc, build, dispatch="eager", **jitter_kw)
    wall_jp, _ = run_hostpath(tcs, jc, build, dispatch="pipelined",
                              **jitter_kw)

    # smoke-sized baseline for benchmarks.ci_smoke (same 50-config shape and
    # rng stream, so the CI gate compares like against like)
    smoke_tcs = tcs[:50] if len(tcs) >= 50 else tcs
    wall_sm, wall_sme, smoke_ratio, _ = smoke_measure(smoke_tcs, jc, build)
    # refreshing the checked-in CI gate baseline is explicit opt-in — a
    # bench run on a loaded machine must not silently move the gate
    if os.environ.get("SMOKE_RECORD") and len(smoke_tcs) == 50:
        baseline_path = record_smoke_baseline({
            "pipelined_smoke_evals_per_s": round(len(smoke_tcs) / wall_sm, 1),
            "eager_smoke_evals_per_s": round(len(smoke_tcs) / wall_sme, 1),
            "pipelined_vs_eager_ratio": round(smoke_ratio, 3)})
        print(f"#   smoke baseline recorded -> {baseline_path}")

    eps_s, eps_b = N_SAMPLES / wall_s, N_SAMPLES / wall_b
    eps_e, eps_p = N_SAMPLES / wall_e, N_SAMPLES / wall_p
    speedup = wall_s / wall_b
    print(f"# evalpath: {N_SAMPLES} configs, {unique_sw} unique sw points "
          f"(hw-ladder-heavy), metrics bit-identical across "
          f"eager/pipelined x json/binary")
    print(f"#   scalar   : {eps_s:8.0f} evals/s  ({compiles_s} compiles, "
          f"{wall_s * 1e3:.1f} ms)")
    print(f"#   batched  : {eps_b:8.0f} evals/s  ({compiles_b} compiles, "
          f"{wall_b * 1e3:.1f} ms)")
    print(f"#   eager    : {eps_e:8.0f} evals/s  (host loop, chunk={batch}, "
          f"{wall_e * 1e3:.1f} ms)")
    print(f"#   pipelined: {eps_p:8.0f} evals/s  (host loop, adaptive, "
          f"{wall_p * 1e3:.1f} ms; {wall_e / wall_p:.2f}x vs eager)")
    print(f"#   jittered fleet (2 clients, 4-8 ms/msg): eager "
          f"{wall_je * 1e3:.0f} ms, pipelined {wall_jp * 1e3:.0f} ms "
          f"-> {wall_je / wall_jp:.2f}x")
    print(f"#   speedup = {speedup:.2f}x (batched vs scalar)")
    return wall_b / N_SAMPLES * 1e6, speedup, {
        "scalar_evals_per_s": round(eps_s, 1),
        "batched_evals_per_s": round(eps_b, 1),
        "eager_evals_per_s": round(eps_e, 1),
        "pipelined_evals_per_s": round(eps_p, 1),
        "pipelined_vs_eager": round(wall_e / wall_p, 3),
        "jitter_speedup": round(wall_je / wall_jp, 3),
        "pipelined_smoke_evals_per_s": round(len(smoke_tcs) / wall_sm, 1),
    }


# ---------------------------------------------------------------------------
# Search-path throughput: model-based search in the loop (this PR's tentpole)
# ---------------------------------------------------------------------------


def bench_searchpath():
    """End-to-end BayesOpt(EHVI)-driven evals/sec + amortized ask cost.

    Four runs of the same N-config exploration over loopback, identical
    seed/workload: prepr = the vendored pre-PR ask wholesale (string-key
    pool loop, naive kernel, loop mask, O(n³) refit per ask — the speedup
    baseline), refit = this PR's vectorized ask but still refitting per ask
    (isolates the incremental-factor gain), sync = inline asks against the
    cached incremental O(n²) factor, async = incremental GP plus
    SearchDriver precompute overlapped with evaluation.  A fifth sync run
    must pick bit-identically to the bare algorithm.  The ask-cost-vs-n
    curve shows the refit path growing ~n³ while the incremental path stays
    flat-ish (amortized O(n²)).  derived = pre-PR wall / async wall
    (target ≥3×).
    """
    space, jc, build = evalpath_workload()
    n = N_SAMPLES

    kw = dict(clients=2, reps=3)
    wall_p, store_p, _ = run_searchpath(n, space, jc, build, driver_mode=None,
                                        gp_mode="prepr", **kw)
    wall_r, store_r, _ = run_searchpath(n, space, jc, build, driver_mode=None,
                                        gp_mode="refit", **kw)
    wall_s, store_s, _ = run_searchpath(n, space, jc, build,
                                        driver_mode="sync",
                                        gp_mode="incremental", **kw)
    wall_a, store_a, dstats = run_searchpath(n, space, jc, build,
                                             driver_mode="async",
                                             gp_mode="incremental", **kw)

    # fleet with 4-8 ms/message latency: here the ask precompute genuinely
    # overlaps in-flight wire+eval time, so async beats even sync-inline
    lat = dict(latency_s=0.004, jitter_s=0.004, clients=2, reps=3)
    wall_ls, _, _ = run_searchpath(n, space, jc, build, driver_mode="sync",
                                   gp_mode="incremental", **lat)
    wall_la, _, _ = run_searchpath(n, space, jc, build, driver_mode="async",
                                   gp_mode="incremental", **lat)

    # sync-mode SearchDriver must pick bit-identically to the bare algorithm
    # (deterministic ask/tell replay — no host-loop timing in the compare)
    identical = sync_picks_identical(space, n=min(n, 120))
    if not identical:
        raise RuntimeError("sync SearchDriver picks diverge from the bare "
                           "algorithm — the pass-through is not transparent")

    curve_r = ask_cost_curve("refit")
    curve_i = ask_cost_curve("incremental")
    cks = sorted(curve_r)
    growth_r = curve_r[cks[-1]] / max(curve_r[cks[-2]], 1e-9)
    growth_i = curve_i[cks[-1]] / max(curve_i[cks[-2]], 1e-9)

    # smoke-sized interleaved baseline for benchmarks.ci_smoke
    smoke_n = min(n, 50)
    wall_sa, wall_sr, smoke_ratio, _ = searchpath_smoke_measure(
        smoke_n, space, jc, build)
    if os.environ.get("SMOKE_RECORD") and smoke_n == 50:
        baseline_path = record_smoke_baseline({
            "searchpath_prepr_vs_async_ratio": round(smoke_ratio, 3),
            "searchpath_async_smoke_evals_per_s":
                round(smoke_n / wall_sa, 1),
            "searchpath_prepr_smoke_evals_per_s":
                round(smoke_n / wall_sr, 1)})
        print(f"#   searchpath smoke baseline recorded -> {baseline_path}")

    speedup = wall_p / wall_a
    print(f"# searchpath: {n}-config BayesOpt(EHVI) exploration, pipelined "
          f"host loop, 2 clients over loopback")
    print(f"#   pre-PR (inline, O(n^3)/ask): {n / wall_p:8.0f} evals/s "
          f"({wall_p * 1e3:.1f} ms)")
    print(f"#   refit (vectorized ask)     : {n / wall_r:8.0f} evals/s "
          f"({wall_r * 1e3:.1f} ms)")
    print(f"#   sync  (incremental GP)     : {n / wall_s:8.0f} evals/s "
          f"({wall_s * 1e3:.1f} ms)")
    print(f"#   async (+driver overlap)    : {n / wall_a:8.0f} evals/s "
          f"({wall_a * 1e3:.1f} ms; driver {dstats})")
    print(f"#   4-8 ms/msg latency fleet: sync {wall_ls * 1e3:.0f} ms, "
          f"async {wall_la * 1e3:.0f} ms -> {wall_ls / wall_la:.2f}x "
          f"(ask precompute hides the wire)")
    print(f"#   amortized tell+ask ms at n={cks}: "
          f"refit {[round(curve_r[k], 2) for k in cks]} "
          f"(x{growth_r:.1f} last doubling), incremental "
          f"{[round(curve_i[k], 2) for k in cks]} (x{growth_i:.1f})")
    print(f"#   smoke ({smoke_n} cfg) pre-PR/async ratio = {smoke_ratio:.2f}")
    print(f"#   speedup = {speedup:.2f}x (async+incremental vs pre-PR "
          f"inline refit); sync picks identical = {identical}")
    row = {
        "searchpath_prepr_evals_per_s": round(n / wall_p, 1),
        "searchpath_refit_evals_per_s": round(n / wall_r, 1),
        "searchpath_sync_evals_per_s": round(n / wall_s, 1),
        "searchpath_async_evals_per_s": round(n / wall_a, 1),
        "searchpath_speedup": round(speedup, 3),
        "searchpath_overlap_speedup": round(wall_ls / wall_la, 3),
        "searchpath_sync_picks_identical": float(identical),
        "searchpath_ask_growth_refit": round(growth_r, 2),
        "searchpath_ask_growth_incremental": round(growth_i, 2),
        "searchpath_smoke_ratio": round(smoke_ratio, 3),
    }
    for k in cks:
        row[f"searchpath_ask_ms_refit_n{k}"] = round(curve_r[k], 3)
        row[f"searchpath_ask_ms_incremental_n{k}"] = round(curve_i[k], 3)

    # big-n jax fast path: flat ask latency past the inducing threshold,
    # plus fused-EHVI equivalence to the numpy reference.  Skipped (with a
    # note) when jax is not importable — the numpy path is the reference
    # and must keep benchmarking without it.
    try:
        import repro.core.search.gp_jax  # noqa: F401
        have_jax = True
    except Exception as e:
        have_jax = False
        print(f"#   gp_mode=jax big-n arm skipped (jax unavailable: {e})")
    if have_jax:
        curve_j = bign_ask_curve("jax", checkpoints=(1000, 5000))
        flat = curve_j[5000] / max(curve_j[1000], 1e-9)
        maxdiff, picks_eq = jax_numpy_ehvi_equiv()
        print(f"#   jax (inducing) tell+ask ms: n=1000 {curve_j[1000]:.2f}, "
              f"n=5000 {curve_j[5000]:.2f} -> flat ratio {flat:.2f} "
              f"(acceptance <= 2.0)")
        print(f"#   jax-vs-numpy EHVI maxdiff {maxdiff:.2e} at n=500 "
              f"(argmax picks equal = {picks_eq})")
        if flat > 2.0:
            raise RuntimeError(
                f"jax big-n ask latency is not flat: n5000/n1000 = "
                f"{flat:.2f} > 2.0 — inducing points are not bounding the "
                f"per-ask cost")
        if maxdiff > 1e-6 or not picks_eq:
            raise RuntimeError(
                f"fused jax EHVI diverges from the numpy staircase "
                f"(maxdiff {maxdiff:.2e}, picks equal = {picks_eq})")
        row.update({
            "searchpath_n5k_ask_ms_n1000": round(curve_j[1000], 3),
            "searchpath_n5k_ask_ms_n5000": round(curve_j[5000], 3),
            "searchpath_n5k_flat_ratio": round(flat, 3),
            "searchpath_jax_ehvi_maxdiff": maxdiff,
        })
        if os.environ.get("SMOKE_RECORD"):
            bign_ratio = searchpath_bign_smoke_measure()
            baseline_path = record_smoke_baseline({
                "searchpath_bign_smoke_flat_ratio": round(bign_ratio, 3)})
            print(f"#   searchpath big-n smoke baseline recorded "
                  f"(flat ratio {bign_ratio:.2f}) -> {baseline_path}")
    return wall_a / n * 1e6, speedup, row


# ---------------------------------------------------------------------------
# Fleet-path: compile-affinity placement + persistent artifact cache (PR 4)
# ---------------------------------------------------------------------------


def bench_fleetpath():
    """Compile-dominated fleet: affinity placement + persistent cache.

    4 clients over loopback, ~8 unique sw fingerprints, each build sleeping
    ``FLEET_COMPILE_MS`` (default 40 ms — still orders of magnitude below a
    real TensorRT engine build) — the regime real Jetson DSE lives in,
    where artifact builds dominate measurements.  Three arms over the
    identical config sequence: rr = affinity off / no cache (PR 2
    placement, so every client compiles nearly every fingerprint),
    affinity = strict compile-affinity placement + cold per-client
    persistent cache, warm = the same sweep repeated against the now-warm
    persistent cache (the restarted-client / repeated-sweep case — zero
    compiles, disk-tier hits only).  Two fleet-store arms (PR 7) ride the
    same sequence: fleet = cold clients, round-robin placement, but a
    host-mediated ``FleetArtifactStore`` in serve mode (exactly unique_sw
    compiles fleet-wide — the store's invariant, vs clients × unique_sw
    for bare rr), and warm-peer = brand-new clients (cold LRU, no disk)
    against the already-populated store (zero compiles, every artifact
    crosses the wire; wall must stay ≤1.3× the warm *local* disk arm).
    Metrics must be bit-identical per config across all arms.  derived =
    rr wall / affinity wall (acceptance ≥2×); fleet-wide n_compiled must
    stay ≤1.25× the unique-fingerprint count, and the warm arm must not
    compile at all.
    """
    import shutil
    import tempfile

    import numpy as np

    from repro.core import TestConfig

    compile_ms = float(os.environ.get("FLEET_COMPILE_MS", "40"))
    space, jc, build = fleetpath_workload(n_fps=8,
                                          compile_cost_s=compile_ms / 1e3)
    rng = np.random.default_rng(0)
    tcs = [TestConfig(i, "toy", "generate", space.sample(rng))
           for i in range(N_SAMPLES)]
    unique_sw = len({jc.cache_key(t) for t in tcs})

    reps = 3
    wall_rr, recs_rr, compiles_rr, _ = run_fleetpath(
        tcs, jc, build, affinity="off", reps=reps)
    cache_root = tempfile.mkdtemp(prefix="jexplore-cache-")
    try:
        # each cold rep gets a fresh cache subtree (the persistent tier must
        # not warm across reps), best-of like the rr arm
        best = None
        for rep in range(reps):
            root = os.path.join(cache_root, f"rep{rep}")
            got = run_fleetpath(tcs, jc, build, affinity="strict",
                                cache_root=root)
            if best is None or got[0] < best[0]:
                best = got[:3] + (root,)
        wall_a, recs_a, compiles_a, warm_root = best
        # the warm arm replays the sweep against any populated rep tree:
        # restarted clients, zero compiles expected
        wall_w, recs_w, compiles_w, infos_w = run_fleetpath(
            tcs, jc, build, affinity="strict", cache_root=warm_root)
    finally:
        shutil.rmtree(cache_root, ignore_errors=True)

    # fleet-store arms (PR 7): cold round-robin fleet with the host-mediated
    # artifact store — exactly unique_sw compiles fleet-wide regardless of
    # placement — then fresh clients against the populated store (warm-peer:
    # every artifact crosses the wire, zero compiles)
    from repro.core import FleetArtifactStore

    fleet_root = tempfile.mkdtemp(prefix="jexplore-fleet-")
    try:
        best_f = None
        for rep in range(reps):
            fstore = FleetArtifactStore(mode="serve")
            got = run_fleetpath(
                tcs, jc, build, affinity="off",
                cache_root=os.path.join(fleet_root, f"rep{rep}"),
                fleet_cache="serve", fleet_store=fstore)
            if best_f is None or got[0] < best_f[0]:
                best_f = got[:3] + (fstore,)
        wall_f, recs_f, compiles_f, fstore = best_f
        fleet_stats = fstore.stats()
        # warm-peer mirrors the warm-local arm's placement (strict
        # affinity) so the walls differ only in where artifacts come
        # from: local disk there, the fleet store over the wire here
        best_wp = None
        for _ in range(reps):
            got = run_fleetpath(tcs, jc, build, affinity="strict",
                                fleet_cache="serve", fleet_store=fstore)
            if best_wp is None or got[0] < best_wp[0]:
                best_wp = got[:3]
        wall_wp, recs_wp, compiles_wp = best_wp
    finally:
        shutil.rmtree(fleet_root, ignore_errors=True)

    for cid, r in recs_rr.items():
        for other, name in ((recs_a, "affinity"), (recs_w, "warm"),
                            (recs_f, "fleet"), (recs_wp, "warmpeer")):
            if r.metrics != other[cid].metrics:
                raise RuntimeError(
                    f"rr/{name} metrics diverge for config {cid}")
    if compiles_w != 0:
        raise RuntimeError(
            f"warm persistent-cache sweep compiled {compiles_w} artifacts "
            f"(expected 0: every fingerprint was already on disk)")
    if compiles_f != unique_sw:
        raise RuntimeError(
            f"cold fleet-store sweep compiled {compiles_f} artifacts "
            f"(expected exactly {unique_sw}: one per unique fingerprint, "
            f"any placement)")
    if compiles_wp != 0:
        raise RuntimeError(
            f"warm-peer sweep compiled {compiles_wp} artifacts (expected "
            f"0: every fingerprint was resident in the fleet store)")
    disk_hits_w = sum(i.get("disk_hits", 0) for i in infos_w)

    # smoke-sized interleaved baselines for benchmarks.ci_smoke
    stcs, sjc, sbuild = fleetpath_smoke_workload()
    wall_sa, wall_sr, smoke_ratio, _ = fleetpath_smoke_measure(
        stcs, sjc, sbuild)
    wall_sc, wall_sw, fleet_smoke_ratio, _, _ = fleet_store_smoke_measure(
        stcs, sjc, sbuild)
    if os.environ.get("SMOKE_RECORD"):
        baseline_path = record_smoke_baseline({
            "fleetpath_rr_vs_affinity_ratio": round(smoke_ratio, 3),
            "fleetpath_affinity_smoke_evals_per_s":
                round(len(stcs) / wall_sa, 1),
            "fleetpath_rr_smoke_evals_per_s":
                round(len(stcs) / wall_sr, 1),
            "fleet_store_cold_vs_warmpeer_ratio":
                round(fleet_smoke_ratio, 3),
            "fleet_store_warmpeer_smoke_evals_per_s":
                round(len(stcs) / wall_sw, 1)})
        print(f"#   fleetpath smoke baseline recorded -> {baseline_path}")

    speedup = wall_rr / wall_a
    compile_ratio = compiles_a / max(unique_sw, 1)
    print(f"# fleetpath: {N_SAMPLES} configs, {unique_sw} unique sw "
          f"fingerprints, 4 clients, {compile_ms:.0f} ms/compile; metrics "
          f"bit-identical across rr/affinity/warm")
    print(f"#   rr (no affinity/cache): {wall_rr * 1e3:8.1f} ms wall, "
          f"{compiles_rr} fleet compiles")
    print(f"#   affinity+cold cache   : {wall_a * 1e3:8.1f} ms wall, "
          f"{compiles_a} fleet compiles ({compile_ratio:.2f}x unique; "
          f"target <= 1.25x)")
    warmpeer_vs_warmlocal = wall_wp / wall_w
    print(f"#   warm persistent cache : {wall_w * 1e3:8.1f} ms wall, "
          f"{compiles_w} compiles, {disk_hits_w} disk hits")
    print(f"#   fleet store (cold, rr): {wall_f * 1e3:8.1f} ms wall, "
          f"{compiles_f} fleet compiles (== {unique_sw} unique), "
          f"{fleet_stats['fleet_hits']} store hits, "
          f"{fleet_stats['fleet_served_mb']:.2f} MB served")
    print(f"#   warm peer (store only): {wall_wp * 1e3:8.1f} ms wall, "
          f"{compiles_wp} compiles, {warmpeer_vs_warmlocal:.2f}x warm-local "
          f"(target <= 1.3x)")
    print(f"#   smoke ({len(stcs)} cfg) rr/affinity ratio = "
          f"{smoke_ratio:.2f}, fleet cold/warm-peer ratio = "
          f"{fleet_smoke_ratio:.2f}")
    print(f"#   speedup = {speedup:.2f}x (rr vs affinity+cache; "
          f"target >= 2x)")
    return wall_a / N_SAMPLES * 1e6, speedup, {
        "fleetpath_rr_wall_ms": round(wall_rr * 1e3, 1),
        "fleetpath_affinity_wall_ms": round(wall_a * 1e3, 1),
        "fleetpath_warm_wall_ms": round(wall_w * 1e3, 1),
        "fleetpath_speedup": round(speedup, 3),
        "fleetpath_unique_sw": unique_sw,
        "fleetpath_rr_compiles": compiles_rr,
        "fleetpath_affinity_compiles": compiles_a,
        "fleetpath_warm_compiles": compiles_w,
        "fleetpath_warm_disk_hits": disk_hits_w,
        "fleetpath_compile_ratio": round(compile_ratio, 3),
        "fleetpath_smoke_ratio": round(smoke_ratio, 3),
        "fleetpath_fleet_wall_ms": round(wall_f * 1e3, 1),
        "fleetpath_fleet_compiles": compiles_f,
        "fleetpath_fleet_hits": fleet_stats["fleet_hits"],
        "fleetpath_fleet_served_mb": fleet_stats["fleet_served_mb"],
        "fleetpath_warmpeer_wall_ms": round(wall_wp * 1e3, 1),
        "fleetpath_warmpeer_compiles": compiles_wp,
        "fleetpath_warmpeer_vs_warmlocal": round(warmpeer_vs_warmlocal, 3),
        "fleet_store_smoke_ratio": round(fleet_smoke_ratio, 3),
    }


# ---------------------------------------------------------------------------
# Table I — the design space
# ---------------------------------------------------------------------------


def bench_table1():
    """Paper Table I: modifiable hardware parameters and their ranges."""
    from repro.configs import SHAPES, get_arch
    from repro.core import tpu_pod_space

    t0 = time.time()
    rows = []
    space = tpu_pod_space(get_arch("glm4-9b"), SHAPES["train_4k"], n_chips=256)
    for k in space:
        lo, hi = k.values[0], k.values[-1]
        rows.append(f"#   {k.name:<14s} {len(k.values):>3d} values "
                    f"({lo} .. {hi})  [{k.kind}]")
    print("# TABLE I (TPU-pod analogue of Jetson Orin knobs)")
    for r in rows:
        print(r)
    print(f"#   total space size = {space.size():,}")
    us = (time.time() - t0) * 1e6
    return us, float(space.size())


# ---------------------------------------------------------------------------
# Fig 2 — Llama2-7B 200-config power/time scatter
# ---------------------------------------------------------------------------


def _fig_bench(arch_name, fig_name):
    store, wall, n_compiles, n = explore_generation(
        arch_name, N_SAMPLES, "random", seed=0,
        csv_path=os.path.join(RESULTS, f"{fig_name}_{arch_name}.csv"))
    import numpy as np

    recs = store.ok_records()
    if not recs:
        raise RuntimeError(f"{fig_name}: all evaluations failed — first error: "
                           + str(store.records[0].metrics.get("error", "?"))[:400])
    t = np.array([r.metrics["time_s"] for r in recs])
    p = np.array([r.metrics["power_w"] for r in recs])
    emc = np.array([r.knobs["hbm_scale"] for r in recs])
    corr = float(np.corrcoef(t, p)[0, 1])
    front = store.pareto_front(["time_s", "power_w"])
    low = emc == emc.min()
    gap = float(t[low].min() - t[~low].max()) if low.any() and (~low).any() else 0.0
    print(f"# {fig_name} ({arch_name}): {len(recs)} configs, "
          f"{n_compiles} compiles, time [{t.min():.2f}, {t.max():.2f}] s, "
          f"power [{p.min():.1f}, {p.max():.1f}] W")
    print(f"#   corr(time,power) = {corr:.3f} (paper: inverse)")
    print(f"#   pareto-front size = {len(front)}")
    print(f"#   lowest-EMC-analogue cluster gap = {gap:.2f} s "
          f"({'DETACHED' if gap > 0 else 'overlapping'})")
    png = os.path.join(RESULTS, f"{fig_name}_{arch_name}.png")
    if scatter_png(store, png, f"{arch_name}: {len(recs)} configs (JExplore-TPU)"):
        print(f"#   scatter -> {png}")
    return wall / max(n, 1) * 1e6, corr


def bench_fig2_llama():
    """Paper Fig. 2: Llama2-7B generation under 200 random configs."""
    return _fig_bench("llama2-7b", "fig2")


def bench_fig4_llava():
    """Paper Fig. 4: LLaVA-1.5-7B (vision-stub) under 200 random configs."""
    return _fig_bench("llava-v1.5-7b", "fig4")


# ---------------------------------------------------------------------------
# Search-algorithm benchmarking ground (paper contribution 3)
# ---------------------------------------------------------------------------


def bench_search_algos():
    """Hypervolume-vs-samples for random/NSGA-II/BO/PAL on the same workload."""
    import numpy as np

    from repro.core.search.hypervolume import hypervolume_2d

    n = max(N_SAMPLES // 4, 30)
    results = {}
    wall_total = evals = 0
    all_pts = []
    for algo in ("random", "nsga2", "bayesopt", "pal"):
        store, wall, _, _ = explore_generation("llama2-7b", n, algo, seed=1,
                                               clients=2)
        pts = store.objective_matrix(["time_s", "power_w"])
        results[algo] = pts
        all_pts.append(pts)
        wall_total += wall
        evals += n
    ref = np.vstack(all_pts).max(0) * 1.1
    print(f"# search-algorithm benchmark ({n} samples each, shared workload)")
    best = None
    for algo, pts in results.items():
        hv = hypervolume_2d(pts, ref)
        print(f"#   {algo:<10s} hypervolume = {hv:.4g}")
        if best is None or hv > best[1]:
            best = (algo, hv)
    print(f"#   best = {best[0]}")
    return wall_total / evals * 1e6, best[1]


# ---------------------------------------------------------------------------
# Roofline table (reads the dry-run artifact)
# ---------------------------------------------------------------------------


def bench_roofline():
    """Summarise results/dryrun.jsonl → §Roofline numbers."""
    import json

    path = os.path.join(RESULTS, "dryrun.jsonl")
    t0 = time.time()
    if not os.path.exists(path):
        print("# roofline: results/dryrun.jsonl missing — run "
              "`python -m repro.launch.dryrun` first")
        return 0.0, 0.0
    cells = bad = 0
    fracs = []
    for line in open(path):
        import json as _j

        r = _j.loads(line)
        if r.get("variant", "baseline") != "baseline" or r.get("mesh") != "16x16":
            continue
        if r.get("status") == "ok" and "roofline" in r:
            cells += 1
            fracs.append(r["roofline"]["roofline_fraction"])
        elif r.get("status") == "failed":
            bad += 1
    import numpy as np

    mean_frac = float(np.mean(fracs)) if fracs else 0.0
    print(f"# roofline: {cells} baseline cells ok, {bad} failed, "
          f"mean roofline fraction = {mean_frac:.3f}")
    return (time.time() - t0) * 1e6 / max(cells, 1), mean_frac


BENCHES = {
    "evalpath": bench_evalpath,
    "searchpath": bench_searchpath,
    "fleetpath": bench_fleetpath,
    "table1": bench_table1,
    "fig2": bench_fig2_llama,
    "fig4": bench_fig4_llava,
    "search": bench_search_algos,
    "roofline": bench_roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    rows = {}
    for name in names:
        out = BENCHES[name]()
        us, derived = out[0], out[1]
        rows[name] = {"us_per_call": round(us, 1), "derived": derived}
        if len(out) > 2:            # extra named sub-metrics (evalpath rows)
            rows[name].update(out[2])
        print(f"{name},{us:.1f},{derived:.6g}")
        sys.stdout.flush()
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "bench.json"), "w") as f:
        json.dump({"n_samples": N_SAMPLES, "benches": rows}, f, indent=2)


if __name__ == "__main__":
    main()
