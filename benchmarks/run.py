import os as _os
_os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = mean wall time per
JClient evaluation; derived = the artifact's headline number).

    PYTHONPATH=src python -m benchmarks.run              # all
    PYTHONPATH=src python -m benchmarks.run fig2 table1  # subset
    BENCH_SAMPLES=50 ... to shrink the 200-config sweeps (CI use)
"""
import json
import os
import sys
import time

from benchmarks.common import (RESULTS, evalpath_workload, explore_generation,
                               run_evalpath, scatter_png)

N_SAMPLES = int(os.environ.get("BENCH_SAMPLES", "200"))


# ---------------------------------------------------------------------------
# Evaluation-path throughput: scalar vs batched DSE loop (PR 1 tentpole)
# ---------------------------------------------------------------------------


def bench_evalpath():
    """Scalar vs batched evaluations/sec on an hw-ladder-heavy random sweep.

    Same N configs both ways through a serving JClient over loopback:
    scalar = one testConfig per message (the seed protocol), batched = one
    columnar frame + group-by-compile + vectorized measurement.  Metrics must
    be bit-identical per config; derived = speedup (×).
    """
    import numpy as np

    from repro.core import TestConfig

    space, jc, build = evalpath_workload()
    rng = np.random.default_rng(0)
    tcs = [TestConfig(i, "toy", "generate", space.sample(rng))
           for i in range(N_SAMPLES)]
    unique_sw = len({jc.cache_key(t) for t in tcs})

    wall_s, compiles_s, res_s = run_evalpath(tcs, jc, build, batched=False)
    wall_b, compiles_b, res_b = run_evalpath(tcs, jc, build, batched=True)

    for cid, r in res_s.items():
        if r["metrics"] != res_b[cid]["metrics"]:
            raise RuntimeError(f"scalar/batched metrics diverge for {cid}: "
                               f"{r['metrics']} != {res_b[cid]['metrics']}")
    eps_s, eps_b = N_SAMPLES / wall_s, N_SAMPLES / wall_b
    speedup = wall_s / wall_b
    print(f"# evalpath: {N_SAMPLES} configs, {unique_sw} unique sw points "
          f"(hw-ladder-heavy), metrics bit-identical")
    print(f"#   scalar : {eps_s:8.0f} evals/s  ({compiles_s} compiles, "
          f"{wall_s * 1e3:.1f} ms)")
    print(f"#   batched: {eps_b:8.0f} evals/s  ({compiles_b} compiles, "
          f"{wall_b * 1e3:.1f} ms)")
    print(f"#   speedup = {speedup:.2f}x")
    return wall_b / N_SAMPLES * 1e6, speedup


# ---------------------------------------------------------------------------
# Table I — the design space
# ---------------------------------------------------------------------------


def bench_table1():
    """Paper Table I: modifiable hardware parameters and their ranges."""
    from repro.configs import SHAPES, get_arch
    from repro.core import tpu_pod_space

    t0 = time.time()
    rows = []
    space = tpu_pod_space(get_arch("glm4-9b"), SHAPES["train_4k"], n_chips=256)
    for k in space:
        lo, hi = k.values[0], k.values[-1]
        rows.append(f"#   {k.name:<14s} {len(k.values):>3d} values "
                    f"({lo} .. {hi})  [{k.kind}]")
    print("# TABLE I (TPU-pod analogue of Jetson Orin knobs)")
    for r in rows:
        print(r)
    print(f"#   total space size = {space.size():,}")
    us = (time.time() - t0) * 1e6
    return us, float(space.size())


# ---------------------------------------------------------------------------
# Fig 2 — Llama2-7B 200-config power/time scatter
# ---------------------------------------------------------------------------


def _fig_bench(arch_name, fig_name):
    store, wall, n_compiles, n = explore_generation(
        arch_name, N_SAMPLES, "random", seed=0,
        csv_path=os.path.join(RESULTS, f"{fig_name}_{arch_name}.csv"))
    import numpy as np

    recs = store.ok_records()
    if not recs:
        raise RuntimeError(f"{fig_name}: all evaluations failed — first error: "
                           + str(store.records[0].metrics.get("error", "?"))[:400])
    t = np.array([r.metrics["time_s"] for r in recs])
    p = np.array([r.metrics["power_w"] for r in recs])
    emc = np.array([r.knobs["hbm_scale"] for r in recs])
    corr = float(np.corrcoef(t, p)[0, 1])
    front = store.pareto_front(["time_s", "power_w"])
    low = emc == emc.min()
    gap = float(t[low].min() - t[~low].max()) if low.any() and (~low).any() else 0.0
    print(f"# {fig_name} ({arch_name}): {len(recs)} configs, "
          f"{n_compiles} compiles, time [{t.min():.2f}, {t.max():.2f}] s, "
          f"power [{p.min():.1f}, {p.max():.1f}] W")
    print(f"#   corr(time,power) = {corr:.3f} (paper: inverse)")
    print(f"#   pareto-front size = {len(front)}")
    print(f"#   lowest-EMC-analogue cluster gap = {gap:.2f} s "
          f"({'DETACHED' if gap > 0 else 'overlapping'})")
    png = os.path.join(RESULTS, f"{fig_name}_{arch_name}.png")
    if scatter_png(store, png, f"{arch_name}: {len(recs)} configs (JExplore-TPU)"):
        print(f"#   scatter -> {png}")
    return wall / max(n, 1) * 1e6, corr


def bench_fig2_llama():
    """Paper Fig. 2: Llama2-7B generation under 200 random configs."""
    return _fig_bench("llama2-7b", "fig2")


def bench_fig4_llava():
    """Paper Fig. 4: LLaVA-1.5-7B (vision-stub) under 200 random configs."""
    return _fig_bench("llava-v1.5-7b", "fig4")


# ---------------------------------------------------------------------------
# Search-algorithm benchmarking ground (paper contribution 3)
# ---------------------------------------------------------------------------


def bench_search_algos():
    """Hypervolume-vs-samples for random/NSGA-II/BO/PAL on the same workload."""
    import numpy as np

    from repro.core.search.hypervolume import hypervolume_2d

    n = max(N_SAMPLES // 4, 30)
    results = {}
    wall_total = evals = 0
    all_pts = []
    for algo in ("random", "nsga2", "bayesopt", "pal"):
        store, wall, _, _ = explore_generation("llama2-7b", n, algo, seed=1,
                                               clients=2)
        pts = store.objective_matrix(["time_s", "power_w"])
        results[algo] = pts
        all_pts.append(pts)
        wall_total += wall
        evals += n
    ref = np.vstack(all_pts).max(0) * 1.1
    print(f"# search-algorithm benchmark ({n} samples each, shared workload)")
    best = None
    for algo, pts in results.items():
        hv = hypervolume_2d(pts, ref)
        print(f"#   {algo:<10s} hypervolume = {hv:.4g}")
        if best is None or hv > best[1]:
            best = (algo, hv)
    print(f"#   best = {best[0]}")
    return wall_total / evals * 1e6, best[1]


# ---------------------------------------------------------------------------
# Roofline table (reads the dry-run artifact)
# ---------------------------------------------------------------------------


def bench_roofline():
    """Summarise results/dryrun.jsonl → §Roofline numbers."""
    import json

    path = os.path.join(RESULTS, "dryrun.jsonl")
    t0 = time.time()
    if not os.path.exists(path):
        print("# roofline: results/dryrun.jsonl missing — run "
              "`python -m repro.launch.dryrun` first")
        return 0.0, 0.0
    cells = bad = 0
    fracs = []
    for line in open(path):
        import json as _j

        r = _j.loads(line)
        if r.get("variant", "baseline") != "baseline" or r.get("mesh") != "16x16":
            continue
        if r.get("status") == "ok" and "roofline" in r:
            cells += 1
            fracs.append(r["roofline"]["roofline_fraction"])
        elif r.get("status") == "failed":
            bad += 1
    import numpy as np

    mean_frac = float(np.mean(fracs)) if fracs else 0.0
    print(f"# roofline: {cells} baseline cells ok, {bad} failed, "
          f"mean roofline fraction = {mean_frac:.3f}")
    return (time.time() - t0) * 1e6 / max(cells, 1), mean_frac


BENCHES = {
    "evalpath": bench_evalpath,
    "table1": bench_table1,
    "fig2": bench_fig2_llama,
    "fig4": bench_fig4_llava,
    "search": bench_search_algos,
    "roofline": bench_roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    rows = {}
    for name in names:
        us, derived = BENCHES[name]()
        rows[name] = {"us_per_call": round(us, 1), "derived": derived}
        print(f"{name},{us:.1f},{derived:.6g}")
        sys.stdout.flush()
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "bench.json"), "w") as f:
        json.dump({"n_samples": N_SAMPLES, "benches": rows}, f, indent=2)


if __name__ == "__main__":
    main()
