"""Shared benchmark plumbing: explore a workload in-process, return the store.

results/bench.json row schema (the benchmarks/ README stanza)
--------------------------------------------------------------
``benchmarks.run`` writes ``results/bench.json`` as
``{"n_samples": N, "benches": {<name>: {<key>: value, ...}, ...}}``.
Every bench row carries ``us_per_call`` (mean wall per evaluation) and
``derived`` (the bench's headline number).  Named sub-metrics:

* ``evalpath`` rows (PR 1/2): ``scalar/batched/eager/pipelined_evals_per_s``,
  ``pipelined_vs_eager``, ``jitter_speedup``,
  ``pipelined_smoke_evals_per_s``.
* ``searchpath`` rows (this PR — BayesOpt-EHVI in the loop, 2 clients):
  - ``searchpath_prepr_evals_per_s``   — the vendored pre-PR ask wholesale
    (string-key pool loop, naive kernel, loop mask, O(n³) refit per ask),
    run inline: the speedup baseline;
  - ``searchpath_refit_evals_per_s``   — this PR's vectorized ask but
    still refitting per ask (isolates the incremental-factor gain);
  - ``searchpath_sync_evals_per_s``    — inline ask, incremental O(n²)
    rank-append GP (factor cached across asks);
  - ``searchpath_async_evals_per_s``   — incremental GP **and** the ask
    precomputed in a SearchDriver worker, overlapped with evaluation;
  - ``searchpath_speedup``             — pre-PR wall / async wall (the
    PR's ≥3× acceptance number);
  - ``searchpath_overlap_speedup``     — sync wall / async wall on a
    4-8 ms/message latency fleet (what precompute alone buys once the
    wire/eval side is nontrivial);
  - ``searchpath_sync_picks_identical``— 1.0 when a deterministic ask/tell
    replay through SearchDriver(sync) picks bit-identically to the bare
    algorithm;
  - ``searchpath_ask_ms_{refit,incremental}_n<k>`` — amortized per-new-
    observation (tell+ask) cost in ms at k observations;
  - ``searchpath_ask_growth_{refit,incremental}`` — cost ratio between the
    two largest checkpoints (≈2³ for O(n³) refit vs ≈flat for the
    incremental path: the curve flattening the ISSUE asks to measure);
  - ``searchpath_smoke_ratio``         — median per-pair pre-PR/async wall
    ratio at smoke size (the CI gate statistic, see ci_smoke.py).
* ``fleetpath`` rows (PR 4 — compile-dominated 4-client fleet, ~8 unique sw
  fingerprints, each build sleeps ``FLEET_COMPILE_MS`` ms):
  - ``fleetpath_rr_wall_ms``           — affinity off / no persistent cache
    (PR 2 placement): the speedup baseline;
  - ``fleetpath_affinity_wall_ms``     — ``affinity="strict"`` placement +
    cold per-client persistent cache (``--cache-dir`` analogue);
  - ``fleetpath_warm_wall_ms``         — the same sweep re-run against the
    now-warm persistent cache (restarted-client / repeated-sweep case);
  - ``fleetpath_speedup``              — rr wall / affinity wall (the PR's
    ≥2× acceptance number);
  - ``fleetpath_unique_sw``            — unique sw fingerprints in the
    config sequence;
  - ``fleetpath_rr_compiles`` / ``fleetpath_affinity_compiles`` /
    ``fleetpath_warm_compiles`` — fleet-wide ``n_compiled`` per arm
    (acceptance: affinity ≤ 1.25× unique_sw; warm == 0);
  - ``fleetpath_warm_disk_hits``       — persistent-tier hits in the warm
    arm (≥ unique_sw: every group rode the disk cache);
  - ``fleetpath_smoke_ratio``          — median per-pair rr/affinity wall
    ratio at smoke size (the CI gate statistic, see ci_smoke.py).
* ``fleetpath`` fleet-store rows (PR 7 — host-mediated artifact sharing,
  ``--fleet-cache serve`` analogue, same 4-client workload):
  - ``fleetpath_fleet_wall_ms``        — cold fleet, round-robin placement,
    fleet store on: every fingerprint compiles exactly once fleet-wide
    and peers fetch it through the host;
  - ``fleetpath_fleet_compiles``       — fleet-wide compiles in that arm
    (acceptance: == unique_sw exactly, vs clients × unique_sw without
    the store);
  - ``fleetpath_fleet_hits`` / ``fleetpath_fleet_served_mb`` — store
    queries served / blob MB pushed to clients in the cold arm;
  - ``fleetpath_warmpeer_wall_ms``     — fresh clients (cold LRU, no
    disk) against the already-populated store: every artifact arrives
    over the wire (acceptance: 0 compiles);
  - ``fleetpath_warmpeer_vs_warmlocal``— warm-peer wall / warm-local
    (disk) wall (acceptance: ≤ 1.3 — a peer fetch costs about what a
    local disk read does at bench blob sizes);
  - ``fleet_store_smoke_ratio``        — median per-pair cold/warm-peer
    wall ratio at smoke size (the CI gate statistic, see ci_smoke.py).
* ``searchpath`` big-n rows (this PR — the ``gp_mode="jax"`` fast path;
  all skipped gracefully when jax is unavailable):
  - ``searchpath_n5k_ask_ms_n1000`` / ``searchpath_n5k_ask_ms_n5000`` —
    per-cycle (tell+ask) wall in ms under ``gp_mode="jax"`` with
    subset-of-data inducing points (threshold 768, so both checkpoints
    sit past it on identical device capacity) at 1 000 and 5 000
    observations (``bign_ask_curve``);
  - ``searchpath_n5k_flat_ratio``      — n5000/n1000 cost ratio: the
    acceptance number (≤ 2.0 — ask latency stays flat once the inducing
    threshold bounds the active set);
  - ``searchpath_jax_ehvi_maxdiff``    — max |EHVI_jax − EHVI_numpy| over
    a shared 256-candidate pool at n=500 (acceptance: ≤ 1e-6 with the
    argmax picks equal — the fused device sweep matches the host
    staircase);
  - ``searchpath_bign_smoke_flat_ratio`` — the same flat-ratio statistic
    at smoke scale (checkpoints 300/1200, inducing 256): the CI gate
    statistic, see ci_smoke.py.
"""
from __future__ import annotations

import os
import sys
import threading
import time
import zlib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

RESULTS = os.path.join(REPO, "results")
SMOKE_BASELINE_PATH = os.path.join(REPO, "benchmarks", "smoke_baseline.json")


def record_smoke_baseline(updates: dict) -> str:
    """Merge ``updates`` into the checked-in CI smoke baseline.

    Always read-merge-write: recording one bench's baseline must never wipe
    the keys other benches' gates rely on.  Callers gate the call on
    ``SMOKE_RECORD`` themselves (refreshing the gate is explicit opt-in).
    """
    import json

    try:
        with open(SMOKE_BASELINE_PATH) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError):
        baseline = {}
    baseline.update(updates)
    with open(SMOKE_BASELINE_PATH, "w") as f:
        json.dump(baseline, f, indent=2)
        f.write("\n")
    return SMOKE_BASELINE_PATH


def generation_space(arch):
    from repro.core.space import DesignSpace, Knob, KIND_HW, KIND_SW
    from repro.roofline import hw as hwmod

    knobs = [
        Knob("clock_scale", hwmod.CLOCK_LADDER, KIND_HW),
        Knob("hbm_scale", hwmod.HBM_LADDER, KIND_HW),
        Knob("ici_scale", hwmod.ICI_LADDER, KIND_HW),
        Knob("dp_degree", (1,), KIND_SW),
        Knob("dtype", ("bfloat16",), KIND_SW),
        Knob("attn_block_q", (128, 256, 512), KIND_SW),
        Knob("attn_block_kv", (128, 256, 512), KIND_SW),
    ]
    return DesignSpace(knobs)


def explore_generation(arch_name: str, n_samples: int, algo_name: str = "random",
                       seed: int = 0, clients: int = 2, chips: int = 8,
                       prompt_len: int = 64, gen_tokens: int = 150,
                       csv_path: str = None):
    """Run the paper's experiment: N sampled configs of a generation workload.

    Returns (store, wall_s, n_compiles, n_evals).
    """
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.core import (ALGORITHMS, JClient, JConfig, JHost, ResultStore,
                            transport)
    from repro.launch.build import build_generation
    from repro.launch.mesh import make_mesh_dp_tp
    from repro.roofline.analysis import summarize
    from repro.roofline.traffic import analytic_hbm_bytes_per_device

    arch = get_arch(arch_name)
    if arch.frontend == "vision":
        # the image contributes n_frontend_tokens to the prompt (paper Fig. 4:
        # image + short text prompt)
        prompt_len = arch.n_frontend_tokens + max(prompt_len - arch.n_frontend_tokens, 32)
    space = generation_space(arch)
    jc = JConfig(space, n_chips=chips)

    def build(tc):
        flags = jc.build_flags(tc.knobs)
        dp, tp = 1, chips
        mesh = make_mesh_dp_tp(dp, tp)
        pre_cell, dec_cell = build_generation(
            arch, mesh, flags, batch=1, prompt_len=prompt_len,
            max_len=prompt_len + gen_tokens + 1)
        pre = summarize(pre_cell.compiled, mesh.size)
        dec = summarize(dec_cell.compiled, mesh.size)
        pre.hbm_est_per_device = analytic_hbm_bytes_per_device(
            arch, ShapeConfig("p", "prefill", prompt_len, 1), flags,
            mesh.size, dp, tp)
        dec.hbm_est_per_device = analytic_hbm_bytes_per_device(
            arch, ShapeConfig("d", "decode", prompt_len + gen_tokens + 1, 1),
            flags, mesh.size, dp, tp)
        return pre, {"decode_artifact": dec, "n_decode_tokens": gen_tokens}

    pair = transport.LoopbackPair(clients)
    cls = [JClient(jc, build, transport=pair.client(i), client_id=i)
           for i in range(clients)]
    for c in cls:
        threading.Thread(target=c.serve,
                         kwargs=dict(poll_s=0.05, idle_limit_s=None),
                         daemon=True).start()
    store = ResultStore(csv_path=csv_path)
    host = JHost(pair.host(), store, timeout_s=900.0, poll_s=0.02)
    algo = ALGORITHMS[algo_name](space, seed=seed)
    t0 = time.time()
    host.explore(algo, arch_name, "generate", n_samples,
                 objectives=("time_s", "power_w"))
    host.stop_clients()
    wall = time.time() - t0
    return store, wall, sum(c.n_compiled for c in cls), n_samples


class _GenArch:
    """Stand-in arch for an hw-ladder-heavy masked space (no attn/ssm knobs)."""
    n_heads = 0
    ssm_state = 0


class _GenShape:
    kind = "generate"
    global_batch = 8


def evalpath_workload(chips: int = 256):
    """Analytic toy workload over the hw-ladder-heavy ``tpu_pod_space``.

    The build is cheap and jax-free on purpose: bench_evalpath measures the
    *evaluation path* (transport framing, artifact cache, measurement sweep),
    not XLA compile time.  Artifacts vary by sw fingerprint so group-by-
    compile is exercised for real.

    Returns (space, jconfig, build_fn).
    """
    from repro.core import JConfig, tpu_pod_space
    from repro.roofline.analysis import Artifact

    def art(f):
        return Artifact(flops_per_device=f, bytes_per_device=2e10,
                        wire_bytes_per_device=1e8, collectives={},
                        arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                        output_bytes=10 ** 6, n_devices=chips)

    space = tpu_pod_space(_GenArch(), _GenShape(), n_chips=chips)
    jc = JConfig(space, n_chips=chips)

    def build(tc):
        # stable digest, not hash(): the workload mix must be identical
        # across runs so bench.json numbers track real throughput changes
        h = zlib.crc32(repr(jc.cache_key(tc)).encode()) % 7 + 1
        return art(5e12 * h), {"decode_artifact": art(1e11 * h),
                               "n_decode_tokens": 100}

    return space, jc, build


def fleetpath_workload(n_fps: int = 8, compile_cost_s: float = 0.025,
                       chips: int = 256):
    """Compile-dominated workload: few unique sw fingerprints, expensive
    builds (an injected sleep — the TensorRT-engine / jit-compile analogue),
    millisecond measurements.  This is the regime JExplore targets on real
    Jetson fleets; ``bench_fleetpath``'s affinity/persistent-cache arms
    measure how well the scheduler amortizes it.  Returns
    (space, jconfig, build_fn).
    """
    from repro.core import JConfig
    from repro.core.space import DesignSpace, KIND_HW, KIND_SW, Knob
    from repro.roofline import hw as hwmod
    from repro.roofline.analysis import Artifact

    def art(f):
        return Artifact(flops_per_device=f, bytes_per_device=2e10,
                        wire_bytes_per_device=1e8, collectives={},
                        arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                        output_bytes=10 ** 6, n_devices=chips)

    space = DesignSpace([
        Knob("clock_scale", hwmod.CLOCK_LADDER, KIND_HW),
        Knob("hbm_scale", hwmod.HBM_LADDER, KIND_HW),
        Knob("ici_scale", hwmod.ICI_LADDER, KIND_HW),
        # one sw knob with n_fps values == n_fps unique compile groups
        Knob("attn_block_q", tuple(64 * (i + 1) for i in range(n_fps)),
             KIND_SW),
    ])
    jc = JConfig(space, n_chips=chips)

    def build(tc):
        if compile_cost_s:
            time.sleep(compile_cost_s)
        h = zlib.crc32(repr(jc.cache_key(tc)).encode()) % 7 + 1
        return art(5e12 * h), {"decode_artifact": art(1e11 * h),
                               "n_decode_tokens": 100}

    return space, jc, build


def run_fleetpath(tcs, jc, build, *, clients: int = 4,
                  affinity: str = "strict", cache_root: str = None,
                  batch_size: int = 12, reps: int = 1,
                  speculate_frac: float = None, timeout_s: float = 120.0,
                  fleet_cache: str = None, fleet_store=None):
    """Drive the full host loop with compile-affinity placement and an
    optional per-client persistent artifact cache
    (``cache_root/client<i>``, each board owning its own disk).

    With ``fleet_cache`` (``"serve"`` | ``"relay"``) clients additionally
    share artifacts through a host-mediated ``FleetArtifactStore``; pass
    a ``fleet_store`` instance to retain it across runs (the warm-peer
    arm: fresh clients, pre-populated store), otherwise one is created
    per rep.

    Same fixed-search replay as ``run_hostpath`` (config_id i ↔ tcs[i]),
    plus fleet-wide compile accounting.  Returns (best_wall_s,
    {config_id: record}, fleet_n_compiled, [per-client cache_info]) with
    the compile counts taken from the best rep.
    """
    import threading
    import time as _time

    from repro.core import (FleetArtifactStore, JClient, JHost, ResultStore,
                            transport)

    best = None
    for _ in range(reps):
        pair = transport.LoopbackPair(clients)
        cls = []
        for i in range(clients):
            cdir = (None if cache_root is None
                    else os.path.join(cache_root, f"client{i}"))
            cl = JClient(jc, build, transport=pair.client(i), client_id=i,
                         cache_size=256, cache_dir=cdir,
                         fleet_mode=fleet_cache)
            cls.append(cl)
            threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.005),
                             daemon=True).start()
        host = JHost(pair.host(), ResultStore(), timeout_s=timeout_s,
                     poll_s=0.002)
        search = _FixedSearch([tc.knobs for tc in tcs])
        fstore = None
        if fleet_cache is not None:
            fstore = (fleet_store if fleet_store is not None
                      else FleetArtifactStore(mode=fleet_cache))
        fp_fn = (jc.cache_key if affinity != "off"
                 or speculate_frac is not None
                 or fleet_cache is not None else None)
        t0 = _time.perf_counter()
        store = host.explore(search, tcs[0].arch, tcs[0].shape, len(tcs),
                             batch_size=batch_size, dispatch="pipelined",
                             affinity=affinity, fingerprint_fn=fp_fn,
                             speculate_frac=speculate_frac,
                             fleet_store=fstore)
        wall = _time.perf_counter() - t0
        host.stop_clients()
        recs = {r.config_id: r for r in store.records}
        if best is None or wall < best[0]:
            best = (wall, recs, sum(c.n_compiled for c in cls),
                    [c.cache_info() for c in cls])
    return best


def fleetpath_smoke_workload():
    """The fixed smoke-sized fleetpath scenario: ci_smoke and the
    SMOKE_RECORD baseline path must measure the identical shape.  Returns
    (tcs, jc, build): 50 configs, 8 fingerprints, 5 ms compile."""
    import numpy as np

    from repro.core import TestConfig

    space, jc, build = fleetpath_workload(n_fps=8, compile_cost_s=0.005)
    rng = np.random.default_rng(0)
    tcs = [TestConfig(i, "toy", "generate", space.sample(rng))
           for i in range(50)]
    return tcs, jc, build


def fleetpath_smoke_measure(tcs, jc, build, reps: int = 5):
    """Interleaved affinity vs round-robin fleetpath pairs.

    No persistent cache, so every rep pays the same cold compiles; the
    per-pair rr/affinity wall ratio is the noise-cancelling CI gate
    statistic (same rationale as ``smoke_measure``).  Returns
    (median_affinity_wall_s, median_rr_wall_s, median_pair_ratio,
    affinity_records).
    """
    awalls, rwalls, ratios = [], [], []
    recs = None
    for _ in range(reps):
        wa, recs, _, _ = run_fleetpath(tcs, jc, build, affinity="strict",
                                       batch_size=6, reps=1)
        wr, _, _, _ = run_fleetpath(tcs, jc, build, affinity="off",
                                    batch_size=6, reps=1)
        awalls.append(wa)
        rwalls.append(wr)
        ratios.append(wr / wa)
    return _median(awalls), _median(rwalls), _median(ratios), recs


def fleet_store_smoke_measure(tcs, jc, build, reps: int = 5):
    """Interleaved cold-fleet vs warm-peer fleetpath pairs (serve mode).

    Per rep: a fresh ``FleetArtifactStore`` is populated by a cold run
    (round-robin placement, every compile announced), then a *second* run
    with brand-new clients (empty LRUs, no disk) reuses the same store —
    every artifact arrives over the wire instead of being recompiled.  The
    per-pair cold/warm-peer wall ratio is the noise-cancelling CI gate
    statistic.  Returns (median_cold_wall_s, median_warmpeer_wall_s,
    median_pair_ratio, cold_compiles, warmpeer_compiles) with compile
    counts from the last rep.
    """
    from repro.core import FleetArtifactStore

    cwalls, wwalls, ratios = [], [], []
    n_cold = n_warm = 0
    for _ in range(reps):
        store = FleetArtifactStore(mode="serve")
        wc, _, n_cold, _ = run_fleetpath(tcs, jc, build, affinity="off",
                                         batch_size=6, reps=1,
                                         fleet_cache="serve",
                                         fleet_store=store)
        # warm-peer rides affinity placement (the realistic deployment,
        # and the same placement bench_fleetpath's warm-local arm uses)
        ww, _, n_warm, _ = run_fleetpath(tcs, jc, build, affinity="strict",
                                         batch_size=6, reps=1,
                                         fleet_cache="serve",
                                         fleet_store=store)
        cwalls.append(wc)
        wwalls.append(ww)
        ratios.append(wc / ww)
    return (_median(cwalls), _median(wwalls), _median(ratios),
            n_cold, n_warm)


def run_evalpath(tcs, jc, build, batched: bool, reps: int = 3):
    """Push N testConfigs through a serving JClient over loopback.

    Scalar mode ping-pongs one config per message (the seed protocol);
    batched mode ships one columnar frame each way.  Returns
    (best_wall_s, n_compiled, {config_id: result}).
    """
    import threading
    import time as _time

    from repro.core import JClient, transport

    best = None
    for _ in range(reps):
        pair = transport.LoopbackPair(1)
        client = JClient(jc, build, transport=pair.client(0), client_id=0)
        threading.Thread(target=client.serve, kwargs=dict(poll_s=0.005),
                         daemon=True).start()
        host = pair.host()
        deadline = _time.monotonic() + 120.0   # fail fast if the client dies
        t0 = _time.perf_counter()
        results = []
        if batched:
            host.push_many(0, [t.to_wire() for t in tcs])
            while len(results) < len(tcs):
                got = host.pull_many(1.0)
                results += got
                if not got and _time.monotonic() > deadline:
                    raise RuntimeError("evalpath client stalled (batched)")
        else:
            for t in tcs:
                host.push(0, t.to_wire())
                while True:
                    m = host.pull(1.0)
                    if m is not None:
                        results.append(m)
                        break
                    if _time.monotonic() > deadline:
                        raise RuntimeError("evalpath client stalled (scalar)")
        wall = _time.perf_counter() - t0
        host.push(0, {"cmd": "stop"})
        if best is None or wall < best[0]:
            best = (wall, client.n_compiled,
                    {r["config_id"]: r for r in results})
    return best


class _FixedSearch:
    """Replays a fixed list of knob dicts, in order (bench determinism:
    every dispatch path sees the identical config sequence)."""

    def __init__(self, knobs_list):
        self._knobs = list(knobs_list)
        self._i = 0

    def ask(self, n):
        out = self._knobs[self._i:self._i + n]
        self._i += len(out)
        return out

    def tell(self, knobs, y):
        pass


from repro.core.transport import ClientTransport as _ClientTransportBase
from repro.core.transport import HostTransport as _HostTransportBase


class _LatencyHostTransport(_HostTransportBase):
    """Simulated per-message network latency, host side (wraps a real
    HostTransport; framing rides on push/pull exactly like the wrapped one).

    Each pushed frame is stamped with a delivery time (now + a deterministic
    jittered latency); the receiving side sleeps until the stamp before
    handing the message over.  Because the stamp is set at *push* time, a
    speculatively pushed chunk's latency overlaps with whatever the client
    is still computing — exactly the overlap pipelined dispatch exploits and
    barrier dispatch cannot.
    """

    def __init__(self, inner, base_s: float, jitter_s: float, seed: int = 0):
        import numpy as np

        self._inner = inner
        self._base = base_s
        self._jitter = jitter_s
        self._rng = np.random.default_rng(seed)

    def _lat(self):
        return self._base + self._jitter * float(self._rng.random())

    def push(self, client_id, msg):
        import time as _t

        self._inner.push(client_id,
                         dict(msg, _deliver_at=_t.monotonic() + self._lat()))

    def pull(self, timeout_s):
        import time as _t

        msg = self._inner.pull(timeout_s)
        if msg is None:
            return None
        due = msg.pop("_deliver_at", None)
        if due is not None:
            _t.sleep(max(0.0, due - _t.monotonic()))
        return msg

    def client_ids(self):
        return self._inner.client_ids()

    def close(self):
        self._inner.close()


class _LatencyClientTransport(_ClientTransportBase):
    """Client-side half of the simulated link (see _LatencyHostTransport)."""

    def __init__(self, inner, base_s: float, jitter_s: float, seed: int = 1):
        import numpy as np

        self._inner = inner
        self._base = base_s
        self._jitter = jitter_s
        self._rng = np.random.default_rng(seed)

    def _lat(self):
        return self._base + self._jitter * float(self._rng.random())

    def pull(self, timeout_s):
        import time as _t

        msg = self._inner.pull(timeout_s)
        if msg is None:
            return None
        due = msg.pop("_deliver_at", None)
        if due is not None:
            _t.sleep(max(0.0, due - _t.monotonic()))
        return msg

    def push(self, msg):
        import time as _t

        self._inner.push(dict(msg, _deliver_at=_t.monotonic() + self._lat()))

    def close(self):
        self._inner.close()


def run_hostpath(tcs, jc, build, *, clients: int = 1, dispatch: str = "eager",
                 batch_size: int = 25, chunk_budget_ms: float = None,
                 codec: str = "json", latency_s: float = 0.0,
                 jitter_s: float = 0.0, reps: int = 3,
                 timeout_s: float = 120.0):
    """Drive the full JHost/DispatchScheduler loop over loopback.

    Replays exactly ``tcs``'s knobs via a fixed search, so every dispatch
    path sees identical configs (config_id i ↔ tcs[i]).  Optional simulated
    per-message latency (base + uniform jitter, deterministic) models a
    fleet over a real network.  Returns (best_wall_s, {config_id: record}).
    """
    import threading
    import time as _time

    from repro.core import JClient, JHost, ResultStore, transport

    best = None
    for rep in range(reps):
        pair = transport.LoopbackPair(clients, codec=codec)
        for i in range(clients):
            ct = pair.client(i)
            if latency_s or jitter_s:
                # later boards sit "farther away": heterogeneous latency
                ct = _LatencyClientTransport(ct, latency_s * (1 + 0.5 * i),
                                             jitter_s, seed=100 + i)
            cl = JClient(jc, build, transport=ct, client_id=i, cache_size=256)
            threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.005),
                             daemon=True).start()
        ht = pair.host()
        if latency_s or jitter_s:
            ht = _LatencyHostTransport(ht, latency_s, jitter_s, seed=7)
        host = JHost(ht, ResultStore(), timeout_s=timeout_s, poll_s=0.002)
        search = _FixedSearch([tc.knobs for tc in tcs])
        t0 = _time.perf_counter()
        store = host.explore(search, tcs[0].arch, tcs[0].shape, len(tcs),
                             batch_size=batch_size, dispatch=dispatch,
                             chunk_budget_ms=chunk_budget_ms)
        wall = _time.perf_counter() - t0
        host.stop_clients()
        recs = {r.config_id: r for r in store.records}
        if best is None or wall < best[0]:
            best = (wall, recs)
    return best


def _make_prepr_bayesopt(space, seed, n_init, pool_size):
    """The pre-PR BayesOpt(EHVI) ask path, vendored as the bench baseline.

    ``bench_searchpath``'s speedup is quoted against "the pre-PR
    inline/refit path"; the live class no longer contains it (the pool is
    vectorized, the mask broadcast, the factor incremental), so the seed's
    ask is reproduced here verbatim: config-at-a-time pool building with
    sorted-string keys, a fresh O(n³) GP refactor per ask, one naive-kernel
    fit/predict per objective, and the Python-loop nondominated mask in the
    EHVI front.  Tells run inline in the host loop, like they did.
    """
    import numpy as np

    from repro.core.search.bayesopt import GP, BayesOpt, ehvi_improvements
    from repro.core.results import _nondominated_mask_loop
    import repro.core.search.bayesopt as bayesopt_mod

    class _PrePRBayesOpt(BayesOpt):
        def __init__(self):
            super().__init__(space, seed=seed, n_init=n_init,
                             pool_size=pool_size, strategy="ehvi",
                             gp_mode="refit")
            self._seen_keys = set()

        def _pool(self):
            pool, keys = [], set()
            while len(pool) < self.pool_size:
                c = self.space.sample(self.rng)
                k = self._key(c)
                if k in keys or k in self._seen_keys:
                    continue
                keys.add(k)
                pool.append(c)
            return pool

        def ask(self, n):
            out = []
            ys = self.observed_values()
            if len(self.history_x) < self.n_init:
                while len(out) < n:
                    c = self.space.sample(self.rng)
                    if self._key(c) not in self._seen_keys:
                        self._seen_keys.add(self._key(c))
                        out.append(c)
                return out
            xs = self.observed_points()
            pool = self._pool()
            xp = np.stack([self.space.encode(c) for c in pool])
            gp = GP().fit_x(xs)
            mus = np.stack([gp.fit_y(ys[:, j]).predict(xp)[0]
                            for j in range(ys.shape[1])], axis=1)
            ref = ys.max(0) * 1.1 + 1e-9
            # the seed's ehvi sweep ran over the loop nondominated mask
            orig = bayesopt_mod.nondominated_mask
            bayesopt_mod.nondominated_mask = _nondominated_mask_loop
            try:
                score = ehvi_improvements(ys, ref, mus)
            finally:
                bayesopt_mod.nondominated_mask = orig
            for i in np.argsort(-score):
                if len(out) >= n:
                    break
                if self._key(pool[i]) not in self._seen_keys:
                    self._seen_keys.add(self._key(pool[i]))
                    out.append(pool[i])
            while len(out) < n:
                out.append(self.space.sample(self.rng))
            return out

    return _PrePRBayesOpt()


def run_searchpath(n_samples, space, jc, build, *, driver_mode=None,
                   gp_mode="incremental", seed=0, clients=1, batch_size=10,
                   pool_size=256, n_init=12, reps=1, timeout_s=120.0,
                   latency_s=0.0, jitter_s=0.0):
    """BayesOpt(EHVI)-in-the-loop exploration over loopback.

    Unlike ``run_hostpath`` (fixed search, measures the dispatch side), the
    searcher here is live model-based search — the hot side this bench
    exercises.  ``driver_mode=None`` runs the bare algorithm inline (the
    pre-SearchDriver host path); ``"sync"``/``"async"`` wrap it in a
    SearchDriver.  ``gp_mode`` picks the surrogate update: ``"refit"`` is
    the per-ask O(n³) refactor, ``"incremental"`` the O(n²) rank-append
    path, and ``"prepr"`` the vendored pre-PR ask wholesale (string-key
    pool loop + naive kernel + loop mask + per-ask refit — the speedup
    baseline).  Returns (best_wall_s, store, driver_stats | None).
    """
    import threading
    import time as _time

    from repro.core import (BayesOpt, JClient, JHost, ResultStore,
                            SearchDriver, transport)

    best = None
    for _ in range(reps):
        pair = transport.LoopbackPair(clients)
        for i in range(clients):
            ct = pair.client(i)
            if latency_s or jitter_s:
                ct = _LatencyClientTransport(ct, latency_s * (1 + 0.5 * i),
                                             jitter_s, seed=100 + i)
            cl = JClient(jc, build, transport=ct, client_id=i,
                         cache_size=256)
            threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.005),
                             daemon=True).start()
        ht = pair.host()
        if latency_s or jitter_s:
            ht = _LatencyHostTransport(ht, latency_s, jitter_s, seed=7)
        host = JHost(ht, ResultStore(), timeout_s=timeout_s,
                     poll_s=0.002)
        if gp_mode == "prepr":
            algo = _make_prepr_bayesopt(space, seed, n_init, pool_size)
        else:
            algo = BayesOpt(space, seed=seed, n_init=n_init,
                            pool_size=pool_size, strategy="ehvi",
                            gp_mode=gp_mode)
        search = (SearchDriver(algo, mode=driver_mode)
                  if driver_mode is not None else algo)
        t0 = _time.perf_counter()
        try:
            store = host.explore(search, "toy", "generate", n_samples,
                                 batch_size=batch_size, dispatch="pipelined")
            # wall is exploration time only: the driver may still be mid-way
            # through a speculative ask that close() would wait out
            wall = _time.perf_counter() - t0
        finally:
            dstats = search.stats() if search is not algo else None
            if search is not algo:
                search.close()
        host.stop_clients()
        if best is None or wall < best[0]:
            best = (wall, store, dstats)
    return best


def ask_cost_curve(gp_mode, checkpoints=(50, 100, 200), pool_size=512,
                   seed=0, timed_iters=3):
    """Amortized (tell+ask) cost per new observation vs observation count.

    Feeds a BayesOpt(EHVI) searcher synthetic observations up to each
    checkpoint, then times a few tell+ask cycles there.  Run over the big
    training space so the pool never nears exhaustion.  Returns
    {n_observations: ms_per_cycle}.
    """
    import time as _time

    import numpy as np

    from repro.core import BayesOpt, tpu_pod_space

    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=seed, n_init=8, pool_size=pool_size,
                    strategy="ehvi", gp_mode=gp_mode)
    rng = np.random.default_rng(seed)
    out = {}
    n = 0
    for ck in checkpoints:
        while n < ck:
            for c in algo.ask(1):
                algo.tell(c, rng.random(2) + 0.5)
                n += 1
        t0 = _time.perf_counter()
        for _ in range(timed_iters):
            c = algo.ask(1)[0]
            algo.tell(c, rng.random(2) + 0.5)
            n += 1
        out[ck] = (_time.perf_counter() - t0) / timed_iters * 1e3
    return out


def bign_ask_curve(gp_mode="jax", checkpoints=(1000, 5000), pool_size=512,
                   inducing=768, fold_block=64, seed=0, timed_iters=5):
    """Ask-latency-vs-n curve at n ≥ 10³ under the jax fast path.

    ``ask_cost_curve`` drives every observation through a full ask/tell
    cycle, which is fine at n ≤ 200 but quadratic wall at n = 5k.  Here
    the searcher is fed synthetic observations directly (``tell``) in
    ``fold_block``-sized blocks with one ``ask(1)`` per block, so the GP
    folds each block in one bounded rank-append — the pow2-padded device
    append block (and hence device capacity) stays O(fold_block), not
    O(n).  At each checkpoint a few live tell+ask cycles are timed.  With
    ``inducing`` set the active set, and with it the per-ask cost, stays
    bounded past the threshold — the flat curve the ISSUE asks to measure.
    The default ``inducing=768`` puts *every* checkpoint past the
    threshold: the active set (and the pow2 device capacity it pads to) is
    then identical at n=1000 and n=5000, so the ratio isolates the O(n)
    host-side bookkeeping rather than comparing a pre-threshold capacity
    against a post-threshold one.  Returns {n_observations: ms_per_cycle}.
    """
    import time as _time

    import numpy as np

    from repro.core import BayesOpt, tpu_pod_space

    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=seed, n_init=8, pool_size=pool_size,
                    strategy="ehvi", gp_mode=gp_mode,
                    inducing_threshold=inducing)
    rng = np.random.default_rng(seed)
    out = {}
    n = 0
    for ck in checkpoints:
        while n < ck:
            for _ in range(min(fold_block, ck - n)):
                algo.tell(space.sample(rng), rng.random(2) + 0.5)
                n += 1
            algo.ask(1)        # folds the pending block into the GP
        # warm the single-append trace at this capacity: the feed folds in
        # fold_block-sized blocks, so the first 1-row append (and any
        # retrace after a capacity doubling) would otherwise pay its jit
        # compile inside the timed window
        algo.tell(algo.ask(1)[0], rng.random(2) + 0.5)
        algo.ask(1)
        cycles = []
        for _ in range(timed_iters):
            t0 = _time.perf_counter()
            c = algo.ask(1)[0]
            algo.tell(c, rng.random(2) + 0.5)
            cycles.append(_time.perf_counter() - t0)
        # median, not mean: one GC pause or scheduler blip in a ~ms cycle
        # would otherwise dominate the checkpoint
        out[ck] = _median(cycles) * 1e3
        n += timed_iters + 1
    return out


def jax_numpy_ehvi_equiv(n=500, pool=256, d=8, seed=0):
    """Max |EHVI_jax − EHVI_numpy| over a shared candidate pool at n obs.

    Same observations, same pool: the numpy reference computes posterior
    means on host and runs the ``ehvi_improvements`` staircase; the jax
    path scores the pool with the fused on-device ``score_ehvi``.  Returns
    (max_abs_diff, argmax_picks_equal) — the n ≤ 500 equivalence half of
    the PR's acceptance criteria.
    """
    import numpy as np

    from repro.core.search.bayesopt import IncrementalGP, ehvi_improvements
    from repro.core.search.gp_jax import JaxIncrementalGP

    rng = np.random.default_rng(seed)
    xs = rng.random((n, d))
    Y = rng.random((n, 2)) + 0.5
    cand = rng.random((pool, d))
    ref_pt = Y.max(0) * 1.1 + 1e-9
    ref = IncrementalGP().fit_x(xs).fit_y_multi(Y)
    want = ehvi_improvements(Y, ref_pt, ref.predict_mean_multi(cand))
    jgp = JaxIncrementalGP().fit_x(xs)
    jgp.fit_y_multi(Y)
    got = jgp.score_ehvi(cand, Y, ref_pt)
    diff = float(np.max(np.abs(np.asarray(got) - want)))
    return diff, bool(int(np.argmax(got)) == int(np.argmax(want)))


def searchpath_bign_smoke_measure(checkpoints=(300, 1200), inducing=256,
                                  reps=3):
    """Smoke-scale flat-ratio statistic for the big-n jax ask path.

    The CI gate tracks the n-high/n-low per-cycle cost ratio from
    ``bign_ask_curve`` at smoke checkpoints — a within-process,
    back-to-back ratio, so machine speed and jit compile time cancel
    (compilation happens during the untimed feed of the first rep; later
    reps ride the trace cache since the pow2 capacities repeat).  Returns
    the median ratio over ``reps`` runs.
    """
    ratios = []
    for rep in range(reps):
        curve = bign_ask_curve("jax", checkpoints=checkpoints,
                               inducing=inducing, seed=rep)
        lo, hi = min(curve), max(curve)
        ratios.append(curve[hi] / max(curve[lo], 1e-9))
    return _median(ratios)


def sync_picks_identical(space, n=120, chunk=10, seed=0):
    """Deterministic ask/tell replay: bare algorithm vs SearchDriver(sync).

    Drives both through the identical ask(chunk)/tell sequence (no host
    loop, no threads, so no timing-dependent want() sizes) and checks every
    pick matches bit-for-bit — the acceptance criterion for the sync
    pass-through.
    """
    import numpy as np

    from repro.core import BayesOpt, SearchDriver

    def mk():
        return BayesOpt(space, seed=seed, n_init=12, pool_size=256,
                        strategy="ehvi", gp_mode="incremental")

    def obj(c):
        x = space.encode(c)
        return np.array([1.0 + x.sum(), 2.0 - x[0] + 0.3 * x[1]])

    bare, drv = mk(), SearchDriver(mk(), mode="sync")
    for _ in range(max(n // chunk, 1)):
        a, b = bare.ask(chunk), drv.ask(chunk)
        if a != b:
            return False
        for c in a:
            y = obj(c)
            bare.tell(c, y)
            drv.tell(c, y)
    return True


def searchpath_smoke_measure(n, space, jc, build, reps=7):
    """Interleaved pre-PR-inline vs async-incremental searchpath pairs.

    Same rationale as ``smoke_measure``: a smoke-sized exploration is ms of
    wall, so the per-pair back-to-back wall ratio (pre-PR/async) is the
    noise-cancelling statistic the CI gate tracks across machines.  Returns
    (median_async_wall_s, median_prepr_wall_s, median_pair_ratio,
    async_store).
    """
    awalls, pwalls, ratios = [], [], []
    store = None
    for _ in range(reps):
        wa, store, _ = run_searchpath(n, space, jc, build,
                                      driver_mode="async",
                                      gp_mode="incremental", reps=1)
        wp, _, _ = run_searchpath(n, space, jc, build, driver_mode=None,
                                  gp_mode="prepr", reps=1)
        awalls.append(wa)
        pwalls.append(wp)
        ratios.append(wp / wa)
    return _median(awalls), _median(pwalls), _median(ratios), store


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def smoke_measure(tcs, jc, build, reps: int = 15):
    """Interleaved pipelined/eager measurement for the CI smoke gate.

    A 50-config exploration is only a few ms of wall, so single runs are
    dominated by scheduler/load noise, and even two medians taken minutes
    (or machines) apart don't compare cleanly.  Each rep therefore runs the
    pipelined and eager paths **back-to-back** — the same load window — and
    the per-pair eager/pipelined wall ratio is the noise-cancelling
    statistic: machine speed and transient load hit both paths alike.

    Returns (median_pipelined_wall_s, median_eager_wall_s,
    median_pair_ratio, pipelined_records).
    """
    pwalls, ewalls, ratios = [], [], []
    recs = None
    for _ in range(reps):
        wp, recs = run_hostpath(tcs, jc, build, dispatch="pipelined",
                                batch_size=10, chunk_budget_ms=5.0, reps=1)
        we, _ = run_hostpath(tcs, jc, build, dispatch="eager",
                             batch_size=10, reps=1)
        pwalls.append(wp)
        ewalls.append(we)
        ratios.append(we / wp)
    return _median(pwalls), _median(ewalls), _median(ratios), recs


def scatter_png(store, path: str, title: str):
    """Paper Fig 2/4-style power-vs-time scatter, colored by the EMC-analogue."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:
        return False
    import numpy as np

    recs = store.ok_records()
    t = np.array([r.metrics["time_s"] for r in recs])
    p = np.array([r.metrics["power_w"] for r in recs])
    emc = np.array([r.knobs["hbm_scale"] for r in recs])
    low = emc == emc.min()
    fig, ax = plt.subplots(figsize=(7, 4.5))
    ax.scatter(t[~low], p[~low], s=14, label="hbm_scale > 1/16")
    ax.scatter(t[low], p[low], s=14, c="tab:red", label="hbm_scale = 1/16 (EMC-analogue)")
    ax.set_xlabel("time (s)")
    ax.set_ylabel("avg power per chip (W)")
    ax.set_title(title)
    ax.legend()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fig.savefig(path, dpi=120, bbox_inches="tight")
    plt.close(fig)
    return True
