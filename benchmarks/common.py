"""Shared benchmark plumbing: explore a workload in-process, return the store."""
from __future__ import annotations

import os
import sys
import threading
import time
import zlib

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

RESULTS = os.path.join(REPO, "results")


def generation_space(arch):
    from repro.core.space import DesignSpace, Knob, KIND_HW, KIND_SW
    from repro.roofline import hw as hwmod

    knobs = [
        Knob("clock_scale", hwmod.CLOCK_LADDER, KIND_HW),
        Knob("hbm_scale", hwmod.HBM_LADDER, KIND_HW),
        Knob("ici_scale", hwmod.ICI_LADDER, KIND_HW),
        Knob("dp_degree", (1,), KIND_SW),
        Knob("dtype", ("bfloat16",), KIND_SW),
        Knob("attn_block_q", (128, 256, 512), KIND_SW),
        Knob("attn_block_kv", (128, 256, 512), KIND_SW),
    ]
    return DesignSpace(knobs)


def explore_generation(arch_name: str, n_samples: int, algo_name: str = "random",
                       seed: int = 0, clients: int = 2, chips: int = 8,
                       prompt_len: int = 64, gen_tokens: int = 150,
                       csv_path: str = None):
    """Run the paper's experiment: N sampled configs of a generation workload.

    Returns (store, wall_s, n_compiles, n_evals).
    """
    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.core import (ALGORITHMS, JClient, JConfig, JHost, ResultStore,
                            transport)
    from repro.launch.build import build_generation
    from repro.launch.mesh import make_mesh_dp_tp
    from repro.roofline.analysis import summarize
    from repro.roofline.traffic import analytic_hbm_bytes_per_device

    arch = get_arch(arch_name)
    if arch.frontend == "vision":
        # the image contributes n_frontend_tokens to the prompt (paper Fig. 4:
        # image + short text prompt)
        prompt_len = arch.n_frontend_tokens + max(prompt_len - arch.n_frontend_tokens, 32)
    space = generation_space(arch)
    jc = JConfig(space, n_chips=chips)

    def build(tc):
        flags = jc.build_flags(tc.knobs)
        dp, tp = 1, chips
        mesh = make_mesh_dp_tp(dp, tp)
        pre_cell, dec_cell = build_generation(
            arch, mesh, flags, batch=1, prompt_len=prompt_len,
            max_len=prompt_len + gen_tokens + 1)
        pre = summarize(pre_cell.compiled, mesh.size)
        dec = summarize(dec_cell.compiled, mesh.size)
        pre.hbm_est_per_device = analytic_hbm_bytes_per_device(
            arch, ShapeConfig("p", "prefill", prompt_len, 1), flags,
            mesh.size, dp, tp)
        dec.hbm_est_per_device = analytic_hbm_bytes_per_device(
            arch, ShapeConfig("d", "decode", prompt_len + gen_tokens + 1, 1),
            flags, mesh.size, dp, tp)
        return pre, {"decode_artifact": dec, "n_decode_tokens": gen_tokens}

    pair = transport.LoopbackPair(clients)
    cls = [JClient(jc, build, transport=pair.client(i), client_id=i)
           for i in range(clients)]
    for c in cls:
        threading.Thread(target=c.serve,
                         kwargs=dict(poll_s=0.05, idle_limit_s=None),
                         daemon=True).start()
    store = ResultStore(csv_path=csv_path)
    host = JHost(pair.host(), store, timeout_s=900.0, poll_s=0.02)
    algo = ALGORITHMS[algo_name](space, seed=seed)
    t0 = time.time()
    host.explore(algo, arch_name, "generate", n_samples,
                 objectives=("time_s", "power_w"))
    host.stop_clients()
    wall = time.time() - t0
    return store, wall, sum(c.n_compiled for c in cls), n_samples


class _GenArch:
    """Stand-in arch for an hw-ladder-heavy masked space (no attn/ssm knobs)."""
    n_heads = 0
    ssm_state = 0


class _GenShape:
    kind = "generate"
    global_batch = 8


def evalpath_workload(chips: int = 256):
    """Analytic toy workload over the hw-ladder-heavy ``tpu_pod_space``.

    The build is cheap and jax-free on purpose: bench_evalpath measures the
    *evaluation path* (transport framing, artifact cache, measurement sweep),
    not XLA compile time.  Artifacts vary by sw fingerprint so group-by-
    compile is exercised for real.

    Returns (space, jconfig, build_fn).
    """
    from repro.core import JConfig, tpu_pod_space
    from repro.roofline.analysis import Artifact

    def art(f):
        return Artifact(flops_per_device=f, bytes_per_device=2e10,
                        wire_bytes_per_device=1e8, collectives={},
                        arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                        output_bytes=10 ** 6, n_devices=chips)

    space = tpu_pod_space(_GenArch(), _GenShape(), n_chips=chips)
    jc = JConfig(space, n_chips=chips)

    def build(tc):
        # stable digest, not hash(): the workload mix must be identical
        # across runs so bench.json numbers track real throughput changes
        h = zlib.crc32(repr(jc.cache_key(tc)).encode()) % 7 + 1
        return art(5e12 * h), {"decode_artifact": art(1e11 * h),
                               "n_decode_tokens": 100}

    return space, jc, build


def run_evalpath(tcs, jc, build, batched: bool, reps: int = 3):
    """Push N testConfigs through a serving JClient over loopback.

    Scalar mode ping-pongs one config per message (the seed protocol);
    batched mode ships one columnar frame each way.  Returns
    (best_wall_s, n_compiled, {config_id: result}).
    """
    import threading
    import time as _time

    from repro.core import JClient, transport

    best = None
    for _ in range(reps):
        pair = transport.LoopbackPair(1)
        client = JClient(jc, build, transport=pair.client(0), client_id=0)
        threading.Thread(target=client.serve, kwargs=dict(poll_s=0.005),
                         daemon=True).start()
        host = pair.host()
        deadline = _time.monotonic() + 120.0   # fail fast if the client dies
        t0 = _time.perf_counter()
        results = []
        if batched:
            host.push_many(0, [t.to_wire() for t in tcs])
            while len(results) < len(tcs):
                got = host.pull_many(1.0)
                results += got
                if not got and _time.monotonic() > deadline:
                    raise RuntimeError("evalpath client stalled (batched)")
        else:
            for t in tcs:
                host.push(0, t.to_wire())
                while True:
                    m = host.pull(1.0)
                    if m is not None:
                        results.append(m)
                        break
                    if _time.monotonic() > deadline:
                        raise RuntimeError("evalpath client stalled (scalar)")
        wall = _time.perf_counter() - t0
        host.push(0, {"cmd": "stop"})
        if best is None or wall < best[0]:
            best = (wall, client.n_compiled,
                    {r["config_id"]: r for r in results})
    return best


class _FixedSearch:
    """Replays a fixed list of knob dicts, in order (bench determinism:
    every dispatch path sees the identical config sequence)."""

    def __init__(self, knobs_list):
        self._knobs = list(knobs_list)
        self._i = 0

    def ask(self, n):
        out = self._knobs[self._i:self._i + n]
        self._i += len(out)
        return out

    def tell(self, knobs, y):
        pass


from repro.core.transport import ClientTransport as _ClientTransportBase
from repro.core.transport import HostTransport as _HostTransportBase


class _LatencyHostTransport(_HostTransportBase):
    """Simulated per-message network latency, host side (wraps a real
    HostTransport; framing rides on push/pull exactly like the wrapped one).

    Each pushed frame is stamped with a delivery time (now + a deterministic
    jittered latency); the receiving side sleeps until the stamp before
    handing the message over.  Because the stamp is set at *push* time, a
    speculatively pushed chunk's latency overlaps with whatever the client
    is still computing — exactly the overlap pipelined dispatch exploits and
    barrier dispatch cannot.
    """

    def __init__(self, inner, base_s: float, jitter_s: float, seed: int = 0):
        import numpy as np

        self._inner = inner
        self._base = base_s
        self._jitter = jitter_s
        self._rng = np.random.default_rng(seed)

    def _lat(self):
        return self._base + self._jitter * float(self._rng.random())

    def push(self, client_id, msg):
        import time as _t

        self._inner.push(client_id,
                         dict(msg, _deliver_at=_t.monotonic() + self._lat()))

    def pull(self, timeout_s):
        import time as _t

        msg = self._inner.pull(timeout_s)
        if msg is None:
            return None
        due = msg.pop("_deliver_at", None)
        if due is not None:
            _t.sleep(max(0.0, due - _t.monotonic()))
        return msg

    def client_ids(self):
        return self._inner.client_ids()

    def close(self):
        self._inner.close()


class _LatencyClientTransport(_ClientTransportBase):
    """Client-side half of the simulated link (see _LatencyHostTransport)."""

    def __init__(self, inner, base_s: float, jitter_s: float, seed: int = 1):
        import numpy as np

        self._inner = inner
        self._base = base_s
        self._jitter = jitter_s
        self._rng = np.random.default_rng(seed)

    def _lat(self):
        return self._base + self._jitter * float(self._rng.random())

    def pull(self, timeout_s):
        import time as _t

        msg = self._inner.pull(timeout_s)
        if msg is None:
            return None
        due = msg.pop("_deliver_at", None)
        if due is not None:
            _t.sleep(max(0.0, due - _t.monotonic()))
        return msg

    def push(self, msg):
        import time as _t

        self._inner.push(dict(msg, _deliver_at=_t.monotonic() + self._lat()))

    def close(self):
        self._inner.close()


def run_hostpath(tcs, jc, build, *, clients: int = 1, dispatch: str = "eager",
                 batch_size: int = 25, chunk_budget_ms: float = None,
                 codec: str = "json", latency_s: float = 0.0,
                 jitter_s: float = 0.0, reps: int = 3,
                 timeout_s: float = 120.0):
    """Drive the full JHost/DispatchScheduler loop over loopback.

    Replays exactly ``tcs``'s knobs via a fixed search, so every dispatch
    path sees identical configs (config_id i ↔ tcs[i]).  Optional simulated
    per-message latency (base + uniform jitter, deterministic) models a
    fleet over a real network.  Returns (best_wall_s, {config_id: record}).
    """
    import threading
    import time as _time

    from repro.core import JClient, JHost, ResultStore, transport

    best = None
    for rep in range(reps):
        pair = transport.LoopbackPair(clients, codec=codec)
        for i in range(clients):
            ct = pair.client(i)
            if latency_s or jitter_s:
                # later boards sit "farther away": heterogeneous latency
                ct = _LatencyClientTransport(ct, latency_s * (1 + 0.5 * i),
                                             jitter_s, seed=100 + i)
            cl = JClient(jc, build, transport=ct, client_id=i, cache_size=256)
            threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.005),
                             daemon=True).start()
        ht = pair.host()
        if latency_s or jitter_s:
            ht = _LatencyHostTransport(ht, latency_s, jitter_s, seed=7)
        host = JHost(ht, ResultStore(), timeout_s=timeout_s, poll_s=0.002)
        search = _FixedSearch([tc.knobs for tc in tcs])
        t0 = _time.perf_counter()
        store = host.explore(search, tcs[0].arch, tcs[0].shape, len(tcs),
                             batch_size=batch_size, dispatch=dispatch,
                             chunk_budget_ms=chunk_budget_ms)
        wall = _time.perf_counter() - t0
        host.stop_clients()
        recs = {r.config_id: r for r in store.records}
        if best is None or wall < best[0]:
            best = (wall, recs)
    return best


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def smoke_measure(tcs, jc, build, reps: int = 15):
    """Interleaved pipelined/eager measurement for the CI smoke gate.

    A 50-config exploration is only a few ms of wall, so single runs are
    dominated by scheduler/load noise, and even two medians taken minutes
    (or machines) apart don't compare cleanly.  Each rep therefore runs the
    pipelined and eager paths **back-to-back** — the same load window — and
    the per-pair eager/pipelined wall ratio is the noise-cancelling
    statistic: machine speed and transient load hit both paths alike.

    Returns (median_pipelined_wall_s, median_eager_wall_s,
    median_pair_ratio, pipelined_records).
    """
    pwalls, ewalls, ratios = [], [], []
    recs = None
    for _ in range(reps):
        wp, recs = run_hostpath(tcs, jc, build, dispatch="pipelined",
                                batch_size=10, chunk_budget_ms=5.0, reps=1)
        we, _ = run_hostpath(tcs, jc, build, dispatch="eager",
                             batch_size=10, reps=1)
        pwalls.append(wp)
        ewalls.append(we)
        ratios.append(we / wp)
    return _median(pwalls), _median(ewalls), _median(ratios), recs


def scatter_png(store, path: str, title: str):
    """Paper Fig 2/4-style power-vs-time scatter, colored by the EMC-analogue."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:
        return False
    import numpy as np

    recs = store.ok_records()
    t = np.array([r.metrics["time_s"] for r in recs])
    p = np.array([r.metrics["power_w"] for r in recs])
    emc = np.array([r.knobs["hbm_scale"] for r in recs])
    low = emc == emc.min()
    fig, ax = plt.subplots(figsize=(7, 4.5))
    ax.scatter(t[~low], p[~low], s=14, label="hbm_scale > 1/16")
    ax.scatter(t[low], p[low], s=14, c="tab:red", label="hbm_scale = 1/16 (EMC-analogue)")
    ax.set_xlabel("time (s)")
    ax.set_ylabel("avg power per chip (W)")
    ax.set_title(title)
    ax.legend()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fig.savefig(path, dpi=120, bbox_inches="tight")
    plt.close(fig)
    return True
