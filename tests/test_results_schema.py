"""ResultStore CSV schema: the header is the union of knob/metric keys, not
whatever the first record happened to carry (a leading timeout used to freeze
a metric-less header and silently drop every later metric)."""
import csv

from repro.core import ResultRecord, ResultStore


def read_rows(path):
    with open(path, newline="") as f:
        return list(csv.DictReader(f))


def timeout_rec(i=0):
    return ResultRecord(config_id=i, arch="a", shape="s",
                        knobs={"clock": 0.5}, metrics={}, status="timeout")


def ok_rec(i=1, **metrics):
    metrics = metrics or {"time_s": 1.5, "power_w": 200.0}
    return ResultRecord(config_id=i, arch="a", shape="s",
                        knobs={"clock": 1.0}, metrics=metrics)


def test_leading_timeout_does_not_freeze_schema(tmp_path):
    """The original bug: first add() with empty metrics -> no metric.*
    columns forever, extrasaction='ignore' eating every later metric."""
    path = str(tmp_path / "r.csv")
    store = ResultStore(csv_path=path)
    store.add(timeout_rec(0))
    store.add(ok_rec(1))
    store.add(ok_rec(2))
    store.close()
    rows = read_rows(path)
    assert len(rows) == 3
    assert "metric.time_s" in rows[0] and "metric.power_w" in rows[0]
    assert rows[0]["metric.time_s"] == ""            # timeout: blank, not lost
    assert float(rows[1]["metric.time_s"]) == 1.5
    assert float(rows[2]["metric.power_w"]) == 200.0


def test_schema_widens_midstream_and_rewrites_earlier_rows(tmp_path):
    path = str(tmp_path / "r.csv")
    store = ResultStore(csv_path=path)
    store.add(ok_rec(0))
    store.add(ok_rec(1, time_s=2.0, power_w=100.0, mem_gb=12.0))
    store.close()
    rows = read_rows(path)
    assert "metric.mem_gb" in rows[0]
    assert rows[0]["metric.mem_gb"] == ""            # earlier row: blank cell
    assert float(rows[1]["metric.mem_gb"]) == 12.0
    assert float(rows[0]["metric.time_s"]) == 1.5    # earlier data preserved


def test_preseeded_schema_avoids_rewrites(tmp_path):
    path = str(tmp_path / "r.csv")
    store = ResultStore(csv_path=path, knob_names=("clock",),
                        metric_names=("time_s", "power_w"))
    store.add(timeout_rec(0))
    store.close()
    rows = read_rows(path)
    assert set(rows[0]) >= {"knob.clock", "metric.time_s", "metric.power_w"}


def test_resume_append_adopts_existing_file(tmp_path):
    path = str(tmp_path / "r.csv")
    first = ResultStore(csv_path=path)
    first.add(ok_rec(0))
    first.close()
    second = ResultStore(csv_path=path)
    second.add(ok_rec(1, time_s=3.0, power_w=50.0, extra=7.0))
    second.close()
    rows = read_rows(path)
    assert len(rows) == 2                            # first run's row kept
    assert float(rows[0]["metric.time_s"]) == 1.5
    assert float(rows[1]["metric.extra"]) == 7.0


def test_to_csv_uses_union_of_all_records(tmp_path):
    store = ResultStore()
    store.add(timeout_rec(0))
    store.add(ok_rec(1))
    store.add(ok_rec(2, time_s=1.0, power_w=2.0, fits_hbm=1.0))
    path = str(tmp_path / "out.csv")
    store.to_csv(path)
    rows = read_rows(path)
    assert len(rows) == 3
    assert {"metric.time_s", "metric.power_w", "metric.fits_hbm"} <= set(rows[0])
    assert float(rows[2]["metric.fits_hbm"]) == 1.0
