"""Checkpointing: exactness, atomicity, keep-k GC, async, crash-restart."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import BuildFlags, Model
from repro.train import (CheckpointManager, TrainStepConfig, adamw,
                         cosine_schedule, init_train_state, make_train_step)


def _mk_state():
    arch = reduced(get_arch("tinyllama-1.1b"))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    opt = adamw(cosine_schedule(1e-3, 5, 100))
    state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(arch, DataConfig(batch=4, seq_len=16, seed=7))
    return state, step, data


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_exact(tmp_path):
    state, step, data = _mk_state()
    ck = CheckpointManager(str(tmp_path), async_save=False)
    ck.save(3, state, block=True)
    restored = ck.restore(3, jax.eval_shape(lambda: state))
    _trees_equal(state, restored)


def test_async_save(tmp_path):
    state, _, _ = _mk_state()
    ck = CheckpointManager(str(tmp_path), async_save=True)
    ck.save(1, state)
    ck.wait()
    assert ck.latest_step() == 1


def test_keep_k_gc(tmp_path):
    state, _, _ = _mk_state()
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, state, block=True)
    assert ck.all_steps() == [3, 4]


def test_torn_write_invisible(tmp_path):
    """A .tmp- directory (torn write) is never listed as a checkpoint."""
    state, _, _ = _mk_state()
    ck = CheckpointManager(str(tmp_path), async_save=False)
    ck.save(5, state, block=True)
    os.makedirs(str(tmp_path / ".tmp-step_00000009"))
    (tmp_path / ".tmp-step_00000009" / "partial.npy").write_bytes(b"junk")
    # a step dir without manifest is also ignored
    os.makedirs(str(tmp_path / "step_00000010"))
    assert ck.all_steps() == [5]
    assert ck.latest_step() == 5


def test_crash_restart_bit_exact(tmp_path):
    """Train 6 steps straight vs train 3 + restore + 3: identical params.
    (The data pipeline is a pure function of step, so resume is exact.)"""
    state_a, step_fn, data = _mk_state()
    for i in range(6):
        state_a, _ = step_fn(state_a, jax.tree.map(jnp.asarray, data.batch(i)))

    state_b, step_fn2, data2 = _mk_state()
    ck = CheckpointManager(str(tmp_path), async_save=False)
    for i in range(3):
        state_b, _ = step_fn2(state_b, jax.tree.map(jnp.asarray, data2.batch(i)))
    ck.save(3, state_b, block=True)
    # --- crash; fresh process state ---
    state_c, step_fn3, data3 = _mk_state()
    state_c = ck.restore(ck.latest_step(), jax.eval_shape(lambda: state_c))
    for i in range(3, 6):
        state_c, _ = step_fn3(state_c, jax.tree.map(jnp.asarray, data3.batch(i)))
    _trees_equal(state_a["params"], state_c["params"])
    _trees_equal(state_a["opt"], state_c["opt"])


def test_elastic_restore_resharded(run_with_devices=None):
    """Checkpoint saved on 1 device restores onto an 8-device mesh."""
    from tests.conftest import run_with_devices as rwd

    code = """
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.configs import get_arch, reduced
from repro.models import BuildFlags, Model
from repro.parallel.sharding import ShardingPolicy
from repro.train import CheckpointManager, adamw, cosine_schedule, init_train_state
from repro.launch.mesh import make_mesh_dp_tp

assert len(jax.devices()) == 8
arch = reduced(get_arch("tinyllama-1.1b"))
model = Model(arch, BuildFlags(dtype="float32", sp=False))
opt = adamw(cosine_schedule(1e-3, 5, 100))
state = init_train_state(model, opt, jax.random.key(0))
with tempfile.TemporaryDirectory() as d:
    ck = CheckpointManager(d, async_save=False)
    ck.save(1, state, block=True)
    mesh = make_mesh_dp_tp(2, 4)
    policy = ShardingPolicy(mesh)
    shardings = policy.param_shardings(jax.eval_shape(lambda: state))
    restored = ck.restore(1, jax.eval_shape(lambda: state), shardings)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored leaves actually carry the new shardings
    leaf = restored["params"]["scan"]["l0"]["mixer"]["wq"]
    assert len(leaf.sharding.device_set) > 1
print("ELASTIC_OK")
"""
    out = rwd(code, n_devices=8)
    assert "ELASTIC_OK" in out
