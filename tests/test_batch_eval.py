"""Batched evaluation fast path: group-by-compile invariants, scalar-vs-batch
metric equality, batch dispatch + straggler requeue, LRU artifact cache, and
vectorized search-internal equivalence (EHVI sweep, PAL Pareto mask)."""
import copy
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core import (BayesOpt, JClient, JConfig, JHost, JMeasure, PAL,
                        RandomSearch, ResultStore, TestConfig, transport,
                        tpu_pod_space)
from repro.core.search import bayesopt as bayesopt_mod
from repro.core.search.bayesopt import (GP, _ehvi_improvements_loop,
                                        _pal_maybe_pareto_loop,
                                        ehvi_improvements, pal_maybe_pareto)
from repro.roofline.analysis import Artifact
from repro.roofline.hw import HwModel, HwModelBatch


def toy_artifact(f=5e12, n_dev=256):
    return Artifact(flops_per_device=f, bytes_per_device=2e10,
                    wire_bytes_per_device=1e8, collectives={},
                    arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                    output_bytes=10 ** 6, n_devices=n_dev)


@pytest.fixture
def jc():
    return JConfig(tpu_pod_space(n_chips=256), n_chips=256)


def sw_dependent_build(jc):
    """build_fn whose artifact (incl. a decode artifact) varies by sw key."""
    def build(tc):
        h = zlib.crc32(repr(jc.cache_key(tc)).encode()) % 7 + 1
        return (toy_artifact(5e12 * h),
                {"decode_artifact": toy_artifact(1e11 * h),
                 "n_decode_tokens": 100})
    return build


def sample_configs(jc, n, seed=0):
    rng = np.random.default_rng(seed)
    return [TestConfig(i, "a", "s", jc.space.sample(rng)) for i in range(n)]


# ---------------------------------------------------------------------------
# group-by-compile + scalar/batch equality
# ---------------------------------------------------------------------------


def test_batch_compiles_once_per_sw_fingerprint(jc):
    tcs = sample_configs(jc, 64)
    client = JClient(jc, sw_dependent_build(jc), cache_size=256)
    client.evaluate_batch(tcs)
    unique_sw = len({jc.cache_key(tc) for tc in tcs})
    assert client.n_compiled == unique_sw
    assert client.n_evaluated == 64
    # a second pass is fully cached: no new compiles
    client.evaluate_batch(tcs)
    assert client.n_compiled == unique_sw


def test_batch_metrics_match_scalar_exactly(jc):
    tcs = sample_configs(jc, 100)
    build = sw_dependent_build(jc)
    scalar = [JClient(jc, build, cache_size=256).evaluate(tc) for tc in tcs]
    batched = JClient(jc, build, cache_size=256).evaluate_batch(tcs)
    for s, b in zip(scalar, batched):
        assert s["config_id"] == b["config_id"]
        assert s["status"] == b["status"] == "ok"
        assert s["metrics"].keys() == b["metrics"].keys()
        for k, v in s["metrics"].items():
            if isinstance(v, float):
                assert b["metrics"][k] == pytest.approx(v, abs=1e-9), k
                # the vectorized sweep mirrors scalar arithmetic bit-for-bit
                assert np.float64(v) == np.float64(b["metrics"][k]), k
            else:
                assert b["metrics"][k] == v, k


def test_batch_build_failure_marks_group_failed(jc):
    def build(tc):
        if tc.knobs.get("fsdp"):
            raise RuntimeError("boom")
        return toy_artifact(), {}

    tcs = sample_configs(jc, 30)
    results = JClient(jc, build).evaluate_batch(tcs)
    for tc, r in zip(tcs, results):
        if tc.knobs.get("fsdp"):
            assert r["status"] == "failed" and "boom" in r["metrics"]["error"]
        else:
            assert r["status"] == "ok" and r["metrics"]["time_s"] > 0


def test_partial_measure_failure_matches_scalar(jc):
    """A measure failing for one hw variant must not fail its group
    siblings — the batch path falls back to per-config scalar parity."""
    class Fussy(JMeasure):
        name = "fussy"

        def measure(self, art, hw, meta):
            if hw.clock_scale < 0.6:
                raise RuntimeError("undervolt")
            return {"ok_metric": hw.clock_scale}

    tcs = sample_configs(jc, 40)
    build = sw_dependent_build(jc)
    scalar = [JClient(jc, build, measures=(Fussy(),)).evaluate(tc)
              for tc in tcs]
    batched = JClient(jc, build, measures=(Fussy(),)).evaluate_batch(tcs)
    assert any(r["status"] == "failed" for r in scalar)      # both kinds occur
    assert any(r["status"] == "ok" for r in scalar)
    for s, b in zip(scalar, batched):
        assert s["status"] == b["status"]
        if s["status"] == "ok":
            assert s["metrics"] == b["metrics"]
        else:
            assert "undervolt" in b["metrics"]["error"]


def test_measure_batch_fallback_for_custom_measures(jc):
    class Custom(JMeasure):
        name = "custom"

        def measure(self, art, hw, meta):
            return {"inv_clock": 1.0 / hw.clock_scale}

    tcs = sample_configs(jc, 12)
    client = JClient(jc, sw_dependent_build(jc), measures=(Custom(),))
    for tc, r in zip(tcs, client.evaluate_batch(tcs)):
        assert r["metrics"]["inv_clock"] == pytest.approx(
            1.0 / tc.knobs["clock_scale"])


def test_hw_model_batch_matches_scalar_roofline(jc):
    rng = np.random.default_rng(1)
    models = [jc.hw_model(jc.space.sample(rng)) for _ in range(40)]
    hwb = HwModelBatch.from_models(models)
    f, hb, wb = 1.3e18, 5.1e15, 2.2e13
    batch = hwb.roofline_terms_batch(f, hb, wb)
    pw = hwb.power_w_batch(f, hb, batch["step_time_s"])
    for i, m in enumerate(models):
        scalar = m.roofline_terms(f, hb, wb)
        for k in ("compute_s", "memory_s", "collective_s", "step_time_s"):
            assert batch[k][i] == scalar[k], k
        assert batch["dominant"][i] == scalar["dominant"]
        assert pw[i] == m.power_w(f, hb, scalar["step_time_s"])


def test_hw_model_roofline_terms_batch_over_traffic_arrays():
    hw = HwModel(n_chips=256, clock_scale=0.75, hbm_scale=1 / 3)
    flops = np.array([1e18, 2e18, 3e18])
    terms = hw.roofline_terms_batch(flops, 4e15, 1e13)
    for i, f in enumerate(flops):
        s = hw.roofline_terms(float(f), 4e15, 1e13)
        assert terms["step_time_s"][i] == s["step_time_s"]
        assert terms["dominant"][i] == s["dominant"]


# ---------------------------------------------------------------------------
# LRU artifact cache
# ---------------------------------------------------------------------------


def test_artifact_cache_is_lru_not_fifo(jc):
    built = []

    def build(tc):
        built.append(jc.cache_key(tc))
        return toy_artifact(), {}

    client = JClient(jc, build, cache_size=2)
    base = jc.space.default()
    a = TestConfig(0, "a", "s", dict(base))
    b = TestConfig(1, "a", "s", dict(base, remat="none"))
    c = TestConfig(2, "a", "s", dict(base, remat="selective"))
    client.evaluate(a)          # cache: [A]
    client.evaluate(b)          # cache: [A, B]
    client.evaluate(a)          # hit refreshes A -> cache: [B, A]
    client.evaluate(c)          # evicts LRU=B (FIFO would evict A)
    n = client.n_compiled
    client.evaluate(a)          # must still be cached
    assert client.n_compiled == n
    info = client.cache_info()
    assert info["hits"] == 2 and info["misses"] == 3
    assert info["evictions"] == 1 and info["currsize"] == 2


# ---------------------------------------------------------------------------
# transport batch framing
# ---------------------------------------------------------------------------


def test_batch_framing_roundtrip():
    pair = transport.LoopbackPair(1)
    host, client = pair.host(), pair.client(0)
    msgs = [{"config_id": i, "x": i * 2} for i in range(5)]
    host.push_many(0, msgs)
    assert client.pull_many(1.0) == msgs          # one frame, five payloads
    client.push_many(msgs[:1])                    # single degenerates to push
    assert host.pull(1.0) == msgs[0]              # scalar peers still interop
    client.push_many(msgs)
    assert host.pull_many(1.0) == msgs


def test_scalar_message_passes_through_pull_many():
    pair = transport.LoopbackPair(1)
    pair.host().push(0, {"config_id": 7})
    assert pair.client(0).pull_many(1.0) == [{"config_id": 7}]


# ---------------------------------------------------------------------------
# JHost batch dispatch + straggler handling
# ---------------------------------------------------------------------------


def _serve_clients(pair, jc, build, ids):
    for i in ids:
        cl = JClient(jc, build, transport=pair.client(i), client_id=i)
        threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.01),
                         daemon=True).start()


def test_batch_mode_explores_all(jc):
    pair = transport.LoopbackPair(2)
    _serve_clients(pair, jc, sw_dependent_build(jc), range(2))
    host = JHost(pair.host(), ResultStore(), timeout_s=30.0, poll_s=0.01)
    store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 40,
                         batch_size=8)
    assert len(store.ok_records()) == 40
    assert len({r.config_id for r in store.records}) == 40


def test_batch_mode_matches_scalar_metrics(jc):
    build = sw_dependent_build(jc)

    def explore(batch_size):
        pair = transport.LoopbackPair(1)
        _serve_clients(pair, jc, build, range(1))
        host = JHost(pair.host(), ResultStore(), timeout_s=30.0, poll_s=0.01)
        store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 25,
                             batch_size=batch_size)
        host.stop_clients()
        return {r.config_id: r for r in store.ok_records()}

    scalar, batched = explore(None), explore(8)
    assert scalar.keys() == batched.keys()
    for cid in scalar:
        assert scalar[cid].knobs == batched[cid].knobs
        for k, v in scalar[cid].metrics.items():
            assert batched[cid].metrics[k] == v, k


def test_pipelined_dispatch_explores_all_and_matches_eager(jc):
    """Double-buffered dispatch + adaptive chunk sizing + binary codec:
    every config completes, metrics identical to the eager barrier path."""
    build = sw_dependent_build(jc)

    def explore(dispatch, codec, budget):
        pair = transport.LoopbackPair(2, codec=codec)
        _serve_clients(pair, jc, build, range(2))
        host = JHost(pair.host(), ResultStore(), timeout_s=30.0, poll_s=0.01)
        store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 40,
                             batch_size=8, dispatch=dispatch,
                             chunk_budget_ms=budget)
        host.stop_clients()
        return {r.config_id: r for r in store.ok_records()}

    eager = explore("eager", "json", None)
    piped = explore("pipelined", "binary", 50.0)
    assert len(piped) == 40 and eager.keys() == piped.keys()
    for cid in eager:
        assert eager[cid].knobs == piped[cid].knobs
        assert eager[cid].metrics == piped[cid].metrics


def test_pipelined_straggler_requeued(jc):
    """A dead client's pipelined chunks are all failed over to the healthy
    one — the exploration still completes every config."""
    pair = transport.LoopbackPair(2)
    _serve_clients(pair, jc, sw_dependent_build(jc), [0])  # client 1 is dead
    host = JHost(pair.host(), ResultStore(), timeout_s=0.1, poll_s=0.01)
    store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 24,
                         batch_size=4, dispatch="pipelined")
    oks = store.ok_records()
    assert len(oks) == 24
    assert all(r.client_id == 0 for r in oks)
    assert 1 in host.quarantined


def test_batch_mode_over_zmq(jc):
    """Columnar batch frames work over the paper's ZMQ PUSH/PULL transport."""
    zmq = pytest.importorskip("zmq")
    rng = np.random.default_rng()
    for attempt in range(5):    # random ports may collide on a busy runner
        ports = [int(p) for p in rng.integers(20000, 40000, size=3)]
        try:
            client_ts = [transport.ZmqClientTransport(
                f"tcp://127.0.0.1:{ports[i]}", f"tcp://127.0.0.1:{ports[2]}")
                for i in range(2)]
            host_t = transport.ZmqHostTransport(
                f"tcp://*:{ports[2]}",
                {i: f"tcp://127.0.0.1:{ports[i]}" for i in range(2)})
            break
        except zmq.error.ZMQError:
            if attempt == 4:
                raise
    build = sw_dependent_build(jc)
    for i, t in enumerate(client_ts):
        cl = JClient(jc, build, transport=t, client_id=i)
        threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.01),
                         daemon=True).start()
    host = JHost(host_t, ResultStore(), timeout_s=30.0, poll_s=0.01)
    store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 24,
                         batch_size=6)
    assert len(store.ok_records()) == 24
    assert all(r.knobs for r in store.ok_records())   # rehydrated echo


def test_batch_straggler_requeued(jc):
    """A dead client's whole chunk is split and re-run on the healthy one."""
    pair = transport.LoopbackPair(2)
    _serve_clients(pair, jc, sw_dependent_build(jc), [0])  # client 1 is dead
    host = JHost(pair.host(), ResultStore(), timeout_s=0.1, poll_s=0.01)
    store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 16,
                         batch_size=4)
    oks = store.ok_records()
    assert len(oks) == 16
    assert all(r.client_id == 0 for r in oks)
    assert 1 in host.quarantined


def test_late_straggler_answer_does_not_free_busy_client(jc):
    """A quarantined straggler's late answer for a re-dispatched config must
    not free the new owner early — a client gets its next chunk only after
    answering its current one itself."""
    from collections import deque

    class LateStragglerTransport(transport.HostTransport):
        def __init__(self):
            self.q = deque()
            self.slow_cids = set()        # configs stuck on dead client 0
            self.outstanding = {0: set(), 1: set()}
            self.double_booked = False

        def client_ids(self):
            return [0, 1]

        @staticmethod
        def _result(msg, client_id):
            return {"config_id": msg["config_id"], "metrics": {"time_s": 1.0,
                    "power_w": 2.0}, "status": "ok", "client_id": client_id,
                    "cached": False, "wall_s": 0.0}

        def push(self, client, msg):
            if msg.get("cmd") == "stop":
                return
            cid = msg["config_id"]
            if self.outstanding[client]:
                self.double_booked = True   # chunk pushed to a busy client
            self.outstanding[client].add(cid)
            if client == 0:
                self.slow_cids.add(cid)     # client 0 stalls (answers late)
                return
            if cid in self.slow_cids:
                # the re-dispatch: the straggler's late answer lands first
                self.q.append(self._result(msg, client_id=0))
            self.q.append(self._result(msg, client_id=1))

        def pull(self, timeout_s):
            if self.q:
                msg = self.q.popleft()
                self.outstanding[msg["client_id"]].discard(msg["config_id"])
                return msg
            time.sleep(timeout_s)
            return None

    t = LateStragglerTransport()
    host = JHost(t, ResultStore(), timeout_s=0.05, poll_s=0.01)
    store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 3)
    assert len(store.ok_records()) == 3
    assert host.quarantined == {0}
    assert not t.double_booked, \
        "host dispatched a new chunk to a client that still owed results"


def test_retry_waits_for_free_client(jc):
    """A timed-out config with retries left is queued, not dropped, when no
    client is free at sweep time (the old code recorded a terminal timeout)."""
    def slow_build(tc):
        time.sleep(0.4)
        return toy_artifact(), {}

    pair = transport.LoopbackPair(2)
    _serve_clients(pair, jc, slow_build, [0])              # client 1 is dead
    host = JHost(pair.host(), ResultStore(), timeout_s=0.5, poll_s=0.01)
    # client 0 is busy 0→0.4 and 0.4→0.8; the dead client's config times out
    # at 0.5 while free is empty and must survive into the pending queue
    store = host.explore(RandomSearch(jc.space, seed=0), "a", "s", 3)
    assert len(store.ok_records()) == 3
    assert not [r for r in store.records if r.status == "timeout"]
    assert 1 in host.quarantined


# ---------------------------------------------------------------------------
# vectorized search internals
# ---------------------------------------------------------------------------


def test_ehvi_improvements_match_loop():
    rng = np.random.default_rng(0)
    for _ in range(20):
        ys = rng.random((int(rng.integers(2, 40)), 2)) * 10
        ref = ys.max(0) * 1.1 + 1e-9
        cand = rng.random((64, 2)) * 12
        fast = ehvi_improvements(ys, ref, cand)
        slow = _ehvi_improvements_loop(ys, ref, cand)
        np.testing.assert_allclose(fast, slow, rtol=1e-10, atol=1e-12)


def test_pal_maybe_pareto_matches_loop():
    rng = np.random.default_rng(0)
    for k in (2, 3):
        ys = rng.random((30, k))
        lcb = rng.random((100, k))
        assert np.array_equal(pal_maybe_pareto(ys, lcb),
                              _pal_maybe_pareto_loop(ys, lcb))


def _toy_objectives(space, knobs):
    x = space.encode(knobs)
    return np.array([2.0 - 1.2 * x[0] + 0.4 * x[1] + 0.1 * np.sin(7 * x.sum()),
                     0.5 + 1.5 * x[0] ** 2 + 0.2 * x[2]])


def _reference_ehvi_ask(algo, n):
    """The seed's per-candidate-hypervolume greedy loop, as a test oracle."""
    ys = algo.observed_values()
    xs = algo.observed_points()
    idx, xp, flats = algo._fresh_pool(algo.pool_size, exclude=algo._seen)
    pool = algo.space.index_decode_batch(idx)
    out = []
    for _ in range(n):
        mus = np.stack([GP().fit(xs, ys[:, j]).predict(xp)[0]
                        for j in range(ys.shape[1])], axis=1)
        ref = ys.max(0) * 1.1 + 1e-9
        score = _ehvi_improvements_loop(ys, ref, mus)   # hypervolume_2d calls
        for i in np.argsort(-score):
            if int(flats[i]) not in algo._seen:
                algo._seen.add(int(flats[i]))
                out.append(pool[i])
                break
        else:
            out.append(algo.space.sample(algo.rng))
    return out


def test_ehvi_ask_vectorized_no_per_candidate_hv_calls(monkeypatch):
    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=3, n_init=12, pool_size=128, strategy="ehvi")
    rng_feed = np.random.default_rng(9)
    for _ in range(64):
        for c in algo.ask(1):
            algo.tell(c, _toy_objectives(space, c))
    reference = copy.deepcopy(algo)

    calls = {"n": 0}
    real_hv = bayesopt_mod.hypervolume_2d

    def counting_hv(*a, **kw):
        calls["n"] += 1
        return real_hv(*a, **kw)

    monkeypatch.setattr(bayesopt_mod, "hypervolume_2d", counting_hv)
    selections = algo.ask(8)
    assert calls["n"] == 0, "ask(8) must not score candidates one hv call at a time"
    assert len(selections) == 8

    # ...and the vectorized sweep picks exactly what the loop oracle picks
    assert selections == _reference_ehvi_ask(reference, 8)


def test_gp_cholesky_reuse_matches_refit():
    rng = np.random.default_rng(0)
    xs = rng.random((32, 5))
    xp = rng.random((10, 5))
    shared = GP().fit_x(xs)
    for _ in range(3):
        y = rng.random(32)
        mu_a, sig_a = shared.fit_y(y).predict(xp)
        mu_b, sig_b = GP().fit(xs, y).predict(xp)
        np.testing.assert_array_equal(mu_a, mu_b)
        np.testing.assert_array_equal(sig_a, sig_b)


def test_pal_ask_still_valid_after_vectorization():
    space = tpu_pod_space(n_chips=256)
    algo = PAL(space, seed=0, n_init=6, pool_size=64)
    for _ in range(20):
        for c in algo.ask(1):
            algo.tell(c, _toy_objectives(space, c))
    picks = algo.ask(4)
    assert len(picks) == 4
    for c in picks:
        for k in space:
            assert c[k.name] in k.values
