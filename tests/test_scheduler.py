"""DispatchScheduler unit tests: driven with a fake clock and no transports,
threads, or sleeps — straggler expiry, retry exhaustion, pipelined
queue-depth invariants, adaptive chunk sizing, duplicate-answer handling."""
import pytest

from repro.core import DispatchScheduler, TestConfig


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def tc(i):
    return TestConfig(i, "a", "s", {"x": i})


def ok(cid, client):
    return {"config_id": cid, "status": "ok", "client_id": client,
            "metrics": {"time_s": 1.0}, "cached": False, "wall_s": 0.0}


def submit_n(sched, n, start=0):
    for i in range(start, start + n):
        sched.submit(tc(i))


def answer_chunk(sched, client, configs):
    for c in configs:
        sched.on_result(ok(c.config_id, client))


# ---------------------------------------------------------------------------
# dispatch policies
# ---------------------------------------------------------------------------


def test_eager_is_depth_one():
    clk = FakeClock()
    s = DispatchScheduler([0, 1], policy="eager", batch_size=2, clock=clk)
    assert s.want() == 4                       # 2 clients x 1 chunk x 2 cfgs
    submit_n(s, 8)
    d = s.next_dispatches()
    # one chunk per client, never a second while the first is unanswered
    assert [(c, len(cfgs)) for c, cfgs in d] == [(0, 2), (1, 2)]
    assert s.next_dispatches() == []
    assert s.want() == 0                       # pipelines full, pending holds 4
    answer_chunk(s, 0, d[0][1])
    d2 = s.next_dispatches()
    assert [(c, len(cfgs)) for c, cfgs in d2] == [(0, 2)]


def test_scalar_mode_is_chunk_of_one():
    s = DispatchScheduler([0], policy="eager", batch_size=None,
                          clock=FakeClock())
    submit_n(s, 3)
    d = s.next_dispatches()
    assert [(c, len(cfgs)) for c, cfgs in d] == [(0, 1)]


def test_pipelined_keeps_two_chunks_deep():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="pipelined", batch_size=3, clock=clk)
    assert s.want() == 6                       # depth 2 x 3 configs
    submit_n(s, 12)
    d = s.next_dispatches()
    assert [(c, len(cfgs)) for c, cfgs in d] == [(0, 3), (0, 3)]
    assert s.next_dispatches() == []           # invariant: never deeper than 2
    # completing the head chunk immediately tops the queue back up to 2
    answer_chunk(s, 0, d[0][1])
    d2 = s.next_dispatches()
    assert [(c, len(cfgs)) for c, cfgs in d2] == [(0, 3)]
    assert len(s.slots[0].chunks) == 2


def test_pipelined_depth_invariant_over_many_rounds():
    clk = FakeClock()
    s = DispatchScheduler([0, 1], policy="pipelined", batch_size=2, clock=clk)
    submit_n(s, 40)
    outstanding = {0: [], 1: []}
    done = 0
    while done < 40:
        for client, cfgs in s.next_dispatches():
            outstanding[client].append(cfgs)
            assert len(s.slots[client].chunks) <= 2
        for client in (0, 1):
            if outstanding[client]:
                clk.advance(0.5)
                answer_chunk(s, client, outstanding[client].pop(0))
                done += 2
    assert s.n_configs_dispatched == 40
    assert not s.chunks and not s.inflight and not s.pending


# ---------------------------------------------------------------------------
# straggler expiry / retries
# ---------------------------------------------------------------------------


def test_straggler_expiry_requeues_with_one_less_retry():
    clk = FakeClock()
    s = DispatchScheduler([0, 1], policy="eager", batch_size=2,
                          timeout_s=10.0, max_retries=2, clock=clk)
    submit_n(s, 4)
    s.next_dispatches()
    answer_chunk(s, 1, [tc(2), tc(3)])         # client 1 answers, 0 stalls
    clk.advance(25.0)                          # past the 2-config deadline (20)
    assert s.expire() == []                    # retries left: nothing terminal
    assert 0 in s.quarantined and s.slots[0].quarantined
    assert [r for _, r in s.pending] == [1, 1]  # retries decremented from 2
    # survivors fail over to the healthy client only
    d = s.next_dispatches()
    assert [c for c, _ in d] == [1]


def test_retry_exhaustion_is_terminal_timeout():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="eager", batch_size=1,
                          timeout_s=5.0, max_retries=0, clock=clk)
    submit_n(s, 1)
    s.next_dispatches()
    clk.advance(6.0)
    dead = s.expire()
    assert [(t.config_id, c) for t, c in dead] == [(0, 0)]
    assert s.stuck()                           # sole client quarantined


def test_pipelined_expiry_fails_over_all_queued_chunks():
    clk = FakeClock()
    s = DispatchScheduler([0, 1], policy="pipelined", batch_size=2,
                          timeout_s=10.0, max_retries=1, clock=clk)
    submit_n(s, 8)
    d = s.next_dispatches()
    chunks0 = [cfgs for c, cfgs in d if c == 0]
    chunks1 = [cfgs for c, cfgs in d if c == 1]
    assert len(chunks0) == 2                   # two chunks queued on each
    answer_chunk(s, 1, chunks1[0])             # client 1 is alive and working
    clk.advance(21.0)                          # client 0's head deadline = 20
    s.expire()
    assert 0 in s.quarantined
    # BOTH of client 0's chunks were failed over, not just the expired head
    assert len(s.pending) == 4
    assert not s.slots[0].chunks
    # client 1's remaining chunk has stacked headroom (deadline 40): survives
    assert 1 not in s.quarantined


def test_queued_chunk_deadline_stacks_behind_predecessor():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="pipelined", batch_size=2,
                          timeout_s=10.0, clock=clk)
    submit_n(s, 4)
    s.next_dispatches()
    head, queued = [s.chunks[c] for c in s.slots[0].chunks]
    assert head.deadline == pytest.approx(20.0)
    assert queued.deadline == pytest.approx(40.0)  # its clock starts at 20
    assert queued.started_at is None


def test_late_straggler_answer_records_but_does_not_free_owner():
    clk = FakeClock()
    s = DispatchScheduler([0, 1], policy="eager", batch_size=1,
                          timeout_s=5.0, max_retries=2, clock=clk)
    submit_n(s, 2)
    s.next_dispatches()                        # cfg0 -> client0, cfg1 -> client1
    clk.advance(1.0)
    s.on_result(ok(1, 1))                      # client1 answers in time
    clk.advance(5.0)
    s.expire()                                 # client0 quarantined, cfg0 requeued
    d = s.next_dispatches()
    assert [(c, cfgs[0].config_id) for c, cfgs in d] == [(1, 0)]
    # the quarantined straggler answers cfg0 first: result is recorded...
    assert s.on_result(ok(0, 0)) is not None
    # ...but client1 still owes its chunk: no new dispatch until it answers
    submit_n(s, 1, start=2)
    assert s.next_dispatches() == []
    assert s.on_result(ok(0, 1)) is None       # duplicate: bookkeeping only
    assert [(c, cfgs[0].config_id)
            for c, cfgs in s.next_dispatches()] == [(1, 2)]


def test_duplicate_result_returns_none():
    s = DispatchScheduler([0], batch_size=1, clock=FakeClock())
    submit_n(s, 1)
    s.next_dispatches()
    assert s.on_result(ok(0, 0)).config_id == 0
    assert s.on_result(ok(0, 0)) is None


# ---------------------------------------------------------------------------
# adaptive chunk sizing
# ---------------------------------------------------------------------------


def test_adaptive_chunk_targets_budget():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="eager", batch_size=4,
                          chunk_budget_s=1.0, clock=clk)
    submit_n(s, 100)
    d = s.next_dispatches()
    assert len(d[0][1]) == 4                   # no EWMA yet: batch_size seeds
    clk.advance(0.4)                           # 0.1 s per config observed
    answer_chunk(s, 0, d[0][1])
    assert s.slots[0].ewma_per_cfg_s == pytest.approx(0.1)
    d2 = s.next_dispatches()
    assert len(d2[0][1]) == 10                 # 1.0 s budget / 0.1 s per cfg


def test_adaptive_chunk_shrinks_for_slow_client_and_clamps():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="eager", batch_size=8,
                          chunk_budget_s=1.0, min_chunk=2, max_chunk=16,
                          ewma_alpha=1.0, clock=clk)
    submit_n(s, 200)
    d = s.next_dispatches()
    clk.advance(8.0)                           # brutally slow: 1 s per config
    answer_chunk(s, 0, d[0][1])
    d2 = s.next_dispatches()
    assert len(d2[0][1]) == 2                  # clamped at min_chunk
    clk.advance(0.0001)                        # now absurdly fast
    answer_chunk(s, 0, d2[0][1])
    assert len(s.next_dispatches()[0][1]) == 16    # clamped at max_chunk


def test_adaptive_per_client_sizing_is_independent():
    clk = FakeClock()
    s = DispatchScheduler([0, 1], policy="pipelined", batch_size=4,
                          chunk_budget_s=1.0, ewma_alpha=1.0, clock=clk)
    submit_n(s, 400)
    d = s.next_dispatches()
    fast = [cfgs for c, cfgs in d if c == 0][0]
    slow = [cfgs for c, cfgs in d if c == 1][0]
    clk.advance(0.2)                           # 0.05 s/cfg on client 0
    answer_chunk(s, 0, fast)
    clk.advance(1.8)                           # 0.5 s/cfg on client 1
    answer_chunk(s, 1, slow)
    sizes = {c: len(cfgs) for c, cfgs in s.next_dispatches()}
    assert sizes[0] > sizes[1]                 # fast client gets bigger chunks
    assert sizes[1] == 2                       # 1.0 / 0.5


def test_coalesced_chunk_folds_into_predecessor_ewma():
    """When the client coalesces both queued chunks into one evaluate_batch,
    their results land in one frame: the successor completes with ~zero
    measured duration.  The EWMA must reflect span/(both chunks' configs),
    not an inflated predecessor sample plus a bogus near-zero sample."""
    clk = FakeClock()
    s = DispatchScheduler([0], policy="pipelined", batch_size=4,
                          chunk_budget_s=1.0, ewma_alpha=1.0, clock=clk)
    submit_n(s, 20)
    d = s.next_dispatches()                    # two 4-config chunks queued
    clk.advance(0.8)                           # client evaluates BOTH: 0.1 s/cfg
    s.note_results()                           # one coalesced result frame
    for _, cfgs in d:
        answer_chunk(s, 0, cfgs)
    assert s.slots[0].ewma_per_cfg_s == pytest.approx(0.8 / 8)
    # the next chunk is sized from the true rate: 1.0 s budget / 0.1 s per cfg
    assert len(s.next_dispatches()[0][1]) == 10


def test_separate_result_frames_are_independent_observations():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="pipelined", batch_size=4,
                          chunk_budget_s=1.0, ewma_alpha=1.0, clock=clk)
    submit_n(s, 20)
    d = s.next_dispatches()
    clk.advance(0.4)
    s.note_results()
    answer_chunk(s, 0, d[0][1])                # chunk 1 alone: 0.1 s/cfg
    clk.advance(0.8)
    s.note_results()
    answer_chunk(s, 0, d[1][1])                # chunk 2 alone: 0.2 s/cfg
    assert s.slots[0].ewma_per_cfg_s == pytest.approx(0.2)


def test_want_accounts_for_pending_backlog():
    s = DispatchScheduler([0], policy="pipelined", batch_size=5,
                          clock=FakeClock())
    assert s.want() == 10
    submit_n(s, 7)
    assert s.want() == 3
    s.next_dispatches()
    assert s.want() == 0                       # both chunk slots occupied


def test_stuck_only_when_everyone_quarantined():
    clk = FakeClock()
    s = DispatchScheduler([0, 1], batch_size=1, timeout_s=1.0,
                          max_retries=0, clock=clk)
    assert not s.stuck()                       # idle but healthy
    submit_n(s, 2)
    s.next_dispatches()
    clk.advance(2.0)
    dead = s.expire()
    assert len(dead) == 2 and s.stuck()
