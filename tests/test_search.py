"""Search-algorithm suite: correctness invariants + they beat/match random on
a seeded synthetic problem (the paper's 'common benchmarking ground')."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container has no hypothesis
    from _propcheck import given, settings, st

from repro.core import (BayesOpt, GridSearch, NSGA2, PAL, RandomSearch,
                        nondominated_mask, tpu_pod_space)
from repro.core.search.hypervolume import hypervolume_2d, hypervolume_3d
from repro.core.search.nsga2 import crowding_distance, fast_nondominated_sort


# ---------------------------------------------------------------------------
# hypervolume
# ---------------------------------------------------------------------------


def test_hypervolume_known():
    pts = np.array([[1.0, 2.0], [2.0, 1.0]])
    #  ref (3,3): union of two 1x... boxes = (3-1)(3-2) + (3-2)(3-1) - overlap (1,1)->...
    # exact: sorted sweep: (3-1)*(3-2)=2 plus (3-2)*(2-1)=1 => 3
    assert hypervolume_2d(pts, np.array([3.0, 3.0])) == pytest.approx(3.0)
    # dominated point adds nothing
    pts2 = np.vstack([pts, [[2.5, 2.5]]])
    assert hypervolume_2d(pts2, np.array([3.0, 3.0])) == pytest.approx(3.0)


def test_hypervolume_3d_box():
    pts = np.array([[1.0, 1.0, 1.0]])
    assert hypervolume_3d(pts, np.array([2.0, 3.0, 4.0])) == pytest.approx(1 * 2 * 3)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.floats(0, 1)), min_size=1, max_size=12))
def test_hypervolume_monotone(points):
    """Adding a point never decreases hypervolume; HV ≤ box(ref)."""
    pts = np.asarray(points)
    ref = np.array([1.5, 1.5])
    hv1 = hypervolume_2d(pts[:-1], ref) if len(pts) > 1 else 0.0
    hv2 = hypervolume_2d(pts, ref)
    assert hv2 >= hv1 - 1e-12
    assert hv2 <= 1.5 * 1.5 + 1e-12


# ---------------------------------------------------------------------------
# non-dominated sorting
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.floats(0, 1), st.floats(0, 1)), min_size=2, max_size=20))
def test_front0_equals_nondominated_mask(points):
    ys = np.asarray(points)
    fronts = fast_nondominated_sort(ys)
    mask = nondominated_mask(ys)
    assert sorted(fronts[0].tolist()) == sorted(np.where(mask)[0].tolist())
    # fronts partition all indices
    allidx = sorted(i for f in fronts for i in f.tolist())
    assert allidx == list(range(len(ys)))


def test_crowding_extremes_infinite():
    ys = np.array([[0.0, 1.0], [0.5, 0.5], [1.0, 0.0]])
    cd = crowding_distance(ys)
    assert np.isinf(cd[0]) and np.isinf(cd[2])
    assert np.isfinite(cd[1])


# ---------------------------------------------------------------------------
# ask/tell on a synthetic problem (no compile, fast)
# ---------------------------------------------------------------------------


def _toy_objectives(space, knobs):
    """Deterministic 2-obj toy: time falls with clock, power rises."""
    x = space.encode(knobs)
    time = 2.0 - 1.2 * x[0] + 0.4 * x[1] + 0.1 * np.sin(7 * x.sum())
    power = 0.5 + 1.5 * x[0] ** 2 + 0.2 * x[2]
    return np.array([time, power])


def _run(algo_cls, space, n, seed=0, **kw):
    algo = algo_cls(space, seed=seed, **kw)
    pts = []
    for _ in range(n):
        cfgs = algo.ask(1)
        for c in cfgs:
            y = _toy_objectives(space, c)
            algo.tell(c, y)
            pts.append(y)
    return np.asarray(pts)


@pytest.mark.parametrize("algo_cls,kw", [
    (RandomSearch, {}), (GridSearch, {}), (NSGA2, {"pop_size": 8}),
    (BayesOpt, {"n_init": 6, "pool_size": 64}),
    (PAL, {"n_init": 6, "pool_size": 64}),
])
def test_algorithms_run_and_cover(algo_cls, kw):
    space = tpu_pod_space(n_chips=256)
    pts = _run(algo_cls, space, 30, **kw)
    assert pts.shape == (30, 2)
    assert np.all(np.isfinite(pts))


def test_guided_beats_random_hypervolume():
    # hw-only space (3 ordered ladders): low-dimensional enough that the RBF
    # GP surrogate is informative at 40 samples
    space = tpu_pod_space(n_chips=256, include_sw=False)
    ref = np.array([2.6, 2.4])
    hv_rand = np.mean([
        hypervolume_2d(_run(RandomSearch, space, 40, seed=s), ref)
        for s in range(3)])
    hv_bo = np.mean([
        hypervolume_2d(_run(BayesOpt, space, 40, seed=s,
                            n_init=8, pool_size=128), ref)
        for s in range(3)])
    # BO must be at least competitive (within 2%) and usually better
    assert hv_bo >= 0.98 * hv_rand


def test_random_dedupes():
    space = tpu_pod_space(n_chips=256)
    algo = RandomSearch(space, seed=0)
    seen = set()
    for c in algo.ask(50):
        key = tuple(sorted((k, str(v)) for k, v in c.items()))
        assert key not in seen
        seen.add(key)


def test_nsga2_generation_evolves():
    space = tpu_pod_space(n_chips=256)
    algo = NSGA2(space, seed=0, pop_size=8)
    first_gen = [algo.ask(1)[0] for _ in range(8)]
    for c in first_gen:
        algo.tell(c, _toy_objectives(space, c))
    nxt = algo.ask(8)  # children must exist after a full generation
    assert len(nxt) == 8
