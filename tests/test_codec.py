"""Wire codec property tests: JsonCodec/BinaryCodec round-trip over row and
columnar frames (including mixed-schema fallback columns), typed-array
packing, sniffing decode, and codec negotiation between mismatched peers."""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container has no hypothesis
    from _propcheck import given, settings, st

from repro.core import transport
from repro.core.codec import (BINARY_CODEC, JSON_CODEC, MAGIC, BinaryCodec,
                              JsonCodec, decode_wire, resolve_codec,
                              sniff_codec)
from repro.core.transport import frame_batch, unframe_batch

CODECS = (JSON_CODEC, BINARY_CODEC)


# ---------------------------------------------------------------------------
# random frame generators (seeded, deterministic)
# ---------------------------------------------------------------------------


def _rand_scalar(rng):
    kind = int(rng.integers(5))
    if kind == 0:
        return int(rng.integers(-10 ** 9, 10 ** 9))
    if kind == 1:
        return float(rng.standard_normal()) * 10.0 ** int(rng.integers(-3, 9))
    if kind == 2:
        return bool(rng.integers(2))
    if kind == 3:
        return f"s{int(rng.integers(1000))}"
    return None


def _rand_col(rng, n):
    """A typed column: every element shares one of int/float/bool/str."""
    kind = int(rng.integers(4))
    if kind == 0:
        return [int(rng.integers(-10 ** 9, 10 ** 9)) for _ in range(n)]
    if kind == 1:
        return [float(rng.standard_normal()) * 10.0 ** int(rng.integers(-3, 9))
                for _ in range(n)]
    if kind == 2:
        return [bool(rng.integers(2)) for _ in range(n)]
    return [f"s{int(rng.integers(1000))}" for _ in range(n)]


def _rand_msgs(rng, uniform_schema: bool, uniform_subschema: bool):
    """A chunk of row messages, optionally with ragged keys/sub-keys."""
    n = int(rng.integers(1, 9))
    keys = [f"k{j}" for j in range(int(rng.integers(1, 5)))]
    subkeys = [f"m{j}" for j in range(int(rng.integers(1, 4)))]
    if uniform_schema and uniform_subschema:
        cols = {k: _rand_col(rng, n) for k in keys}
        subcols = {s: _rand_col(rng, n) for s in subkeys}
        return [{"config_id": i, **{k: cols[k][i] for k in keys},
                 "metrics": {s: subcols[s][i] for s in subkeys}}
                for i in range(n)]
    msgs = []
    for i in range(n):
        m = {"config_id": i}
        for k in keys:
            if rng.random() < 0.7:
                m[k] = _rand_scalar(rng)  # ragged: key missing in some rows
        use = subkeys if (uniform_subschema or rng.random() < 0.5) \
            else subkeys[:int(rng.integers(1, len(subkeys) + 1))]
        m["metrics"] = {s: _rand_scalar(rng) for s in use}
        msgs.append(m)
    return msgs


# ---------------------------------------------------------------------------
# round-trip properties
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_codec_roundtrip_uniform_columnar(seed):
    rng = np.random.default_rng(seed)
    msgs = _rand_msgs(rng, uniform_schema=True, uniform_subschema=True)
    frame = frame_batch(msgs)
    for codec in CODECS:
        back = decode_wire(codec.encode(frame))
        assert back == frame, codec.name
        assert unframe_batch(back) == msgs, codec.name


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_codec_roundtrip_mixed_schema_fallback(seed):
    """Ragged keys force the row frame; ragged sub-keys force a per-column
    row fallback — both must survive either codec byte-exactly."""
    rng = np.random.default_rng(seed)
    msgs = _rand_msgs(rng, uniform_schema=False, uniform_subschema=False)
    frame = frame_batch(msgs)
    for codec in CODECS:
        back = decode_wire(codec.encode(frame))
        assert back == frame, codec.name
        assert unframe_batch(back) == msgs, codec.name


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_codec_roundtrip_preserves_types_exactly(seed):
    """ints stay ints, floats round-trip bit-for-bit, bools stay bools."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 20))
    frame = frame_batch([
        {"config_id": i,
         "f": float(rng.standard_normal() * 10.0 ** int(rng.integers(-300, 300))),
         "i": int(rng.integers(-2 ** 62, 2 ** 62)),
         "b": bool(rng.integers(2)),
         "metrics": {"time_s": float(rng.random()), "steps": int(rng.integers(100))}}
        for i in range(n)])
    for codec in CODECS:
        back = decode_wire(codec.encode(frame))
        for col in ("f", "i", "b", "config_id"):
            for orig, rt in zip(frame["plain"][col], back["plain"][col]):
                assert type(rt) is type(orig), (codec.name, col)
                if isinstance(orig, float):
                    assert np.float64(orig).tobytes() == np.float64(rt).tobytes()
                else:
                    assert rt == orig


def test_binary_packs_numeric_columns_compactly():
    """Numeric-heavy columnar frames must actually use the binary container
    and come out smaller than JSON."""
    n = 512
    rng = np.random.default_rng(0)
    frame = frame_batch([
        {"config_id": i, "metrics": {"time_s": float(rng.random()),
                                     "power_w": float(rng.random() * 400)}}
        for i in range(n)])
    bin_wire = BINARY_CODEC.encode(frame)
    json_wire = JSON_CODEC.encode(frame)
    assert bin_wire[:len(MAGIC)] == MAGIC
    assert len(bin_wire) < len(json_wire) * 0.7
    assert decode_wire(bin_wire) == frame


def test_binary_degenerates_to_json_when_nothing_packs():
    msg = {"cmd": "stop"}
    wire = BINARY_CODEC.encode(msg)
    assert wire[:1] != MAGIC[:1]          # plain JSON bytes
    assert json.loads(wire.decode()) == msg
    assert decode_wire(wire) == msg


def test_sniff_and_resolve():
    assert sniff_codec(JSON_CODEC.encode({"a": 1})) == "json"
    frame = frame_batch([{"x": float(i)} for i in range(4)])
    assert sniff_codec(BINARY_CODEC.encode(frame)) == "binary"
    assert isinstance(resolve_codec("json"), JsonCodec)
    assert isinstance(resolve_codec("binary"), BinaryCodec)
    assert resolve_codec(BINARY_CODEC) is BINARY_CODEC
    with pytest.raises(ValueError):
        resolve_codec("protobuf")


def test_oversize_ints_fall_back_to_json_column():
    frame = frame_batch([{"x": 2 ** 80 + i} for i in range(3)])
    wire = BINARY_CODEC.encode(frame)
    assert decode_wire(wire) == frame


# ---------------------------------------------------------------------------
# bytes payloads (artifact blobs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("blob", [
    b"",                                       # empty blob
    b"\x00\xff\xfe\x93" * 7,                   # non-UTF8, contains MAGIC byte
    np.random.default_rng(0).bytes(2 << 20),   # large (2 MiB) engine-sized
], ids=["empty", "non_utf8", "large"])
def test_bytes_scalar_roundtrip_both_codecs(blob):
    msg = {"cmd": "artifact_put", "addr": "deadbeef", "blob": blob,
           "client_id": 3}
    for codec in CODECS:
        back = decode_wire(codec.encode(msg))
        assert back == msg, codec.name
        assert isinstance(back["blob"], bytes), codec.name


def test_bytes_column_roundtrip_with_length_table():
    """Uniform bytes lists pack per-element (tag "Y"); ragged lengths and
    empty elements must survive exactly."""
    rng = np.random.default_rng(1)
    msg = {"cmd": "artifact_chunk", "addr": "cafe",
           "chunks": [rng.bytes(int(n)) for n in (0, 1, 4096, 17)]}
    for codec in CODECS:
        back = decode_wire(codec.encode(msg))
        assert back == msg, codec.name
        assert all(isinstance(c, bytes) for c in back["chunks"]), codec.name


def test_bytearray_encodes_and_decodes_as_bytes():
    msg = {"blob": bytearray(b"\x01\x02\x93\x00")}
    for codec in CODECS:
        back = decode_wire(codec.encode(msg))
        assert back["blob"] == bytes(msg["blob"]), codec.name
        assert isinstance(back["blob"], bytes), codec.name


def test_mixed_bytes_and_numeric_columnar_frame():
    """A frame carrying both typed numeric columns and a raw blob must pack
    both through the binary container and stay lossless under JSON."""
    n = 64
    rng = np.random.default_rng(2)
    frame = frame_batch([
        {"config_id": i, "x": float(rng.random()),
         "metrics": {"time_s": float(rng.random())}} for i in range(n)])
    frame["blob"] = rng.bytes(100_000)
    bin_wire = BINARY_CODEC.encode(frame)
    assert bin_wire[:len(MAGIC)] == MAGIC
    for codec in CODECS:
        back = decode_wire(codec.encode(frame))
        assert back == frame, codec.name
        assert unframe_batch({k: v for k, v in back.items()
                              if k != "blob"}) is not None, codec.name


def test_binary_blob_avoids_base64_inflation():
    """The whole point of the raw-blob segment: wire size tracks blob size,
    while the JSON fallback pays the ~33% base64 tax (but still works)."""
    blob = np.random.default_rng(3).bytes(1 << 20)
    msg = {"cmd": "artifact_put", "addr": "ab" * 32, "blob": blob}
    bin_wire = BINARY_CODEC.encode(msg)
    json_wire = JSON_CODEC.encode(msg)
    assert len(bin_wire) < len(blob) + 1024          # header-only overhead
    assert len(json_wire) > len(blob) * 1.30         # base64 inflation
    # JSON fallback is real JSON text with the tagged wrapper
    doc = json.loads(json_wire.decode("utf-8"))
    assert doc["blob"].keys() == {"__b64__"}
    assert decode_wire(json_wire) == msg
    assert decode_wire(bin_wire) == msg


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_codec_roundtrip_random_bytes_frames(seed):
    """Property: any mix of scalar blobs, bytes columns, numeric columns and
    nested dicts round-trips byte-exactly through both codecs."""
    rng = np.random.default_rng(seed)
    msg = {"cmd": "artifact_put", "seq": int(rng.integers(10 ** 6))}
    if rng.random() < 0.8:
        msg["blob"] = rng.bytes(int(rng.integers(0, 5000)))
    if rng.random() < 0.5:
        msg["chunks"] = [rng.bytes(int(rng.integers(0, 200)))
                         for _ in range(int(rng.integers(1, 6)))]
    if rng.random() < 0.5:
        msg["xs"] = [float(rng.standard_normal())
                     for _ in range(int(rng.integers(1, 40)))]
    if rng.random() < 0.5:
        msg["meta"] = {"inner": rng.bytes(int(rng.integers(0, 300))),
                       "n": int(rng.integers(100))}
    for codec in CODECS:
        back = decode_wire(codec.encode(msg))
        assert back == msg, codec.name


# ---------------------------------------------------------------------------
# negotiation: binary host ↔ json client
# ---------------------------------------------------------------------------


def test_binary_host_json_client_interop_and_negotiation():
    pair = transport.LoopbackPair(1, codec="json")
    host = pair.host(codec="binary")
    client = pair.client(0)               # json-configured
    msgs = [{"config_id": i, "x": float(i)} for i in range(5)]
    host.push_many(0, msgs)
    assert client.pull_many(1.0) == msgs  # sniffing decode reads binary
    client.push_many(msgs)
    raw = pair.to_host.get(timeout=1.0)
    # the client answers in the codec the host spoke — binary
    assert sniff_codec(raw) == "binary"
    assert unframe_batch(decode_wire(raw)) == msgs


def test_json_host_binary_capable_client_stays_json():
    pair = transport.LoopbackPair(1, codec="binary")
    host = pair.host(codec="json")
    client = pair.client(0)               # binary-configured
    msgs = [{"config_id": i, "x": float(i)} for i in range(4)]
    host.push_many(0, msgs)
    assert client.pull_many(1.0) == msgs
    client.push_many(msgs)
    raw = pair.to_host.get(timeout=1.0)
    assert sniff_codec(raw) == "json"     # negotiated down to the host's codec


def test_zmq_close_is_idempotent():
    zmq = pytest.importorskip("zmq")
    rng = np.random.default_rng()
    for attempt in range(5):    # random ports may collide on a busy runner
        ports = [int(p) for p in rng.integers(20000, 40000, size=2)]
        try:
            client = transport.ZmqClientTransport(
                f"tcp://127.0.0.1:{ports[0]}", f"tcp://127.0.0.1:{ports[1]}")
            host = transport.ZmqHostTransport(
                f"tcp://*:{ports[1]}", {0: f"tcp://127.0.0.1:{ports[0]}"})
            break
        except zmq.error.ZMQError:
            if attempt == 4:
                raise
    host.push(0, {"config_id": 1})
    assert client.pull(2.0) == {"config_id": 1}
    for t in (host, client):
        t.close()
        t.close()                          # double-close must not raise


def test_zmq_own_ctx_teardown():
    pytest.importorskip("zmq")
    rng = np.random.default_rng()
    ports = [int(p) for p in rng.integers(40000, 60000, size=2)]
    client = transport.ZmqClientTransport(
        f"tcp://127.0.0.1:{ports[0]}", f"tcp://127.0.0.1:{ports[1]}",
        own_ctx=True)
    host = transport.ZmqHostTransport(
        f"tcp://*:{ports[1]}", {0: f"tcp://127.0.0.1:{ports[0]}"},
        own_ctx=True)
    host.close()
    client.close()
    assert host._ctx.closed and client._ctx.closed
    host.close()                           # still idempotent after term
    client.close()
