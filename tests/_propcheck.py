"""Pure-pytest stand-in for the ``hypothesis`` API surface these tests use.

The container does not ship ``hypothesis`` and nothing may be pip-installed,
so the property tests fall back to this deterministic sampler: ``@given``
draws ``max_examples`` seeded samples per strategy and runs the test body
once per draw.  Only the strategies actually used by this suite are
implemented (integers / floats / lists / tuples / sampled_from).  When the
real ``hypothesis`` is available the test modules import it instead, so this
shim never shadows the real thing.
"""
from __future__ import annotations

import functools
import inspect
import zlib
from typing import Any, Callable, Sequence

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


class _StrategiesModule:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
        return _Strategy(
            lambda rng: float(min_value + (max_value - min_value) * rng.random()))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elements))

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> _Strategy:
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


st = _StrategiesModule()


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator recording max_examples; other hypothesis knobs are no-ops."""
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy, **kw_strategies: _Strategy):
    """Run the wrapped test once per seeded draw of the strategies."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings sits *above* @given, so it stamps the attribute on this
            # wrapper object — read it from the wrapper, not the inner fn
            n = getattr(wrapper, "_propcheck_max_examples",
                        getattr(fn, "_propcheck_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            # crc32, not hash(): str hash is salted per process and would make
            # a CI failure unreproducible locally
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = [s.example(rng) for s in strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **drawn_kw, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsified on example {i}: args={drawn} kwargs={drawn_kw}"
                    ) from e
            return None

        # pytest must only see the leading (fixture) parameters — the trailing
        # ones are filled from the right by the positional strategies, and the
        # keyword ones by kw_strategies (mirrors hypothesis' fixture support)
        params = list(inspect.signature(fn).parameters.values())
        n_pos = len(strategies)
        keep = [p for p in (params[:len(params) - n_pos] if n_pos else params)
                if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(keep)
        del wrapper.__wrapped__
        return wrapper
    return deco
