"""JHost/JClient/JConfig/JMeasure integration: Algorithm 1 end-to-end over
loopback and ZMQ transports, compile-cache behaviour, straggler handling."""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container has no hypothesis
    from _propcheck import given, settings, st

from repro.core import (JClient, JConfig, JHost, JMemory, JPower, JTime,
                        RandomSearch, ResultStore, TestConfig, transport,
                        tpu_pod_space)
from repro.core.space import KIND_SW
from repro.roofline.analysis import Artifact


def toy_artifact(n_dev=256):
    return Artifact(flops_per_device=5e12, bytes_per_device=2e10,
                    wire_bytes_per_device=1e8, collectives={},
                    arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                    output_bytes=10 ** 6, n_devices=n_dev)


def toy_build(tc):
    return toy_artifact(), {}


@pytest.fixture
def jc():
    return JConfig(tpu_pod_space(n_chips=256), n_chips=256)


# ---------------------------------------------------------------------------
# JConfig
# ---------------------------------------------------------------------------


def test_cache_key_ignores_hw_knobs(jc):
    space = jc.space
    base = space.default()
    tc1 = TestConfig(0, "a", "train_4k", dict(base))
    hw_changed = dict(base, clock_scale=0.5, hbm_scale=1 / 16)
    tc2 = TestConfig(1, "a", "train_4k", hw_changed)
    assert jc.cache_key(tc1) == jc.cache_key(tc2)
    sw_changed = dict(base, remat="none")
    tc3 = TestConfig(2, "a", "train_4k", sw_changed)
    assert jc.cache_key(tc1) != jc.cache_key(tc3)


def test_hw_model_ladders(jc):
    hw = jc.hw_model({"clock_scale": 0.5, "hbm_scale": 0.25, "ici_scale": 0.5})
    full = jc.hw_model({})
    assert hw.peak_flops == pytest.approx(0.5 * full.peak_flops)
    assert hw.hbm_bw == pytest.approx(0.25 * full.hbm_bw)
    assert hw.ici_bw == pytest.approx(0.5 * full.ici_bw)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_space_encode_decode_roundtrip(seed):
    space = tpu_pod_space(n_chips=256)
    cfg = space.sample(np.random.default_rng(seed))
    assert space.decode(space.encode(cfg)) == cfg
    assert space.index_decode(space.index_encode(cfg)) == cfg
    mutated = space.mutate(cfg, np.random.default_rng(seed))
    for k in space:
        assert mutated[k.name] in k.values


# ---------------------------------------------------------------------------
# JMeasure: knob physics
# ---------------------------------------------------------------------------


def test_jtime_monotone_in_ladders(jc):
    art = toy_artifact()
    t_fast = JTime().measure(art, jc.hw_model({}), {})["time_s"]
    t_slow = JTime().measure(art, jc.hw_model({"clock_scale": 0.5,
                                               "hbm_scale": 1 / 16}), {})["time_s"]
    assert t_slow > t_fast


def test_jpower_tradeoff(jc):
    """Higher clock: faster but more power — the paper's inverse correlation."""
    art = toy_artifact()
    hw_hi, hw_lo = jc.hw_model({}), jc.hw_model({"clock_scale": 0.5})
    t_hi = JTime().measure(art, hw_hi, {})["time_s"]
    t_lo = JTime().measure(art, hw_lo, {})["time_s"]
    p_hi = JPower().measure(art, hw_hi, {})["power_w"]
    p_lo = JPower().measure(art, hw_lo, {})["power_w"]
    assert t_hi < t_lo and p_hi > p_lo


def test_jmemory_reports_fit(jc):
    art = toy_artifact()
    m = JMemory().measure(art, jc.hw_model({}), {})
    assert m["fits_hbm"] == 1.0 and m["mem_bytes"] > 0


# ---------------------------------------------------------------------------
# JClient: caching + failure capture
# ---------------------------------------------------------------------------


def test_jclient_cache(jc):
    calls = []

    def build(tc):
        calls.append(tc.config_id)
        return toy_artifact(), {}

    client = JClient(jc, build)
    base = jc.space.default()
    r1 = client.evaluate(TestConfig(0, "a", "s", dict(base)))
    r2 = client.evaluate(TestConfig(1, "a", "s", dict(base, clock_scale=0.5)))
    r3 = client.evaluate(TestConfig(2, "a", "s", dict(base, remat="none")))
    assert len(calls) == 2            # hw knob change did not recompile
    assert not r1["cached"] and r2["cached"] and not r3["cached"]
    assert r1["metrics"]["time_s"] > 0


def test_jclient_failure_reported(jc):
    def build(tc):
        raise RuntimeError("boom")

    client = JClient(jc, build)
    r = client.evaluate(TestConfig(0, "a", "s", jc.space.default()))
    assert r["status"] == "failed" and "boom" in r["metrics"]["error"]


# ---------------------------------------------------------------------------
# End-to-end over both transports
# ---------------------------------------------------------------------------


def _explore(host_transport, client_transports, jc, n=20, build=toy_build,
             timeout_s=20.0):
    clients = [JClient(jc, build, transport=t, client_id=i)
               for i, t in enumerate(client_transports)]
    threads = [threading.Thread(target=c.serve,
                                kwargs=dict(poll_s=0.01, idle_limit_s=None),
                                daemon=True) for c in clients]
    for t in threads:
        t.start()
    host = JHost(host_transport, ResultStore(), timeout_s=timeout_s, poll_s=0.01)
    algo = RandomSearch(jc.space, seed=0)
    host.explore(algo, "toy", "train_4k", n)
    host.stop_clients()
    return host.store


def test_loopback_end_to_end(jc):
    pair = transport.LoopbackPair(3)
    store = _explore(pair.host(), [pair.client(i) for i in range(3)], jc, 25)
    assert len(store.ok_records()) == 25
    front = store.pareto_front(["time_s", "power_w"])
    assert 1 <= len(front) <= 25


def test_zmq_end_to_end(jc):
    """The paper's actual transport: ZMQ PUSH/PULL over TCP."""
    ports = [np.random.randint(20000, 40000) for _ in range(3)]
    host_t = None
    try:
        client_ts = [transport.ZmqClientTransport(
            f"tcp://127.0.0.1:{ports[i]}", f"tcp://127.0.0.1:{ports[2]}")
            for i in range(2)]
        host_t = transport.ZmqHostTransport(
            f"tcp://*:{ports[2]}",
            {i: f"tcp://127.0.0.1:{ports[i]}" for i in range(2)})
        store = _explore(host_t, client_ts, jc, 12)
        assert len(store.ok_records()) == 12
    finally:
        pass  # sockets closed by GC; LINGER=0


def test_straggler_requeued(jc):
    """A dead client's config is re-dispatched to a healthy one."""
    pair = transport.LoopbackPair(2)

    # client 1 never serves (simulated node failure) — no thread started
    good = JClient(jc, toy_build, transport=pair.client(0), client_id=0)
    threading.Thread(target=good.serve,
                     kwargs=dict(poll_s=0.01, idle_limit_s=None),
                     daemon=True).start()

    host = JHost(pair.host(), ResultStore(), timeout_s=0.5, poll_s=0.01)
    algo = RandomSearch(jc.space, seed=0)
    host.explore(algo, "toy", "s", 8)
    oks = host.store.ok_records()
    assert len(oks) == 8                      # everything completed
    assert all(r.client_id == 0 for r in oks)  # ...on the healthy client
    assert 1 in host.quarantined


def test_results_csv_roundtrip(tmp_path, jc):
    pair = transport.LoopbackPair(1)
    store = _explore(pair.host(), [pair.client(0)], jc, 5)
    path = str(tmp_path / "r.csv")
    store.to_csv(path)
    import csv

    with open(path) as f:
        rows = list(csv.DictReader(f))
    assert len(rows) == 5
    assert any(k.startswith("metric.time_s") for k in rows[0])
    assert any(k.startswith("knob.") for k in rows[0])


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.floats(0.1, 10), st.floats(0.1, 10)),
                min_size=1, max_size=30))
def test_pareto_front_is_nondominated(pts):
    """Property: no returned front point is dominated by any record."""
    from repro.core.results import ResultRecord

    store = ResultStore()
    for i, (a, b) in enumerate(pts):
        store.add(ResultRecord(i, "a", "s", {}, {"t": a, "p": b}))
    front = store.pareto_front(["t", "p"])
    assert front
    arr = np.asarray(pts)
    for r in front:
        y = np.array([r.metrics["t"], r.metrics["p"]])
        dominated = np.any(np.all(arr <= y, 1) & np.any(arr < y, 1))
        assert not dominated
