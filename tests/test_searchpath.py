"""Search-throughput suite (async search–evaluate overlap PR): incremental
GP rank-append vs full-refit equivalence (incl. the doubling-growth
boundary), SearchDriver sync bit-identity + async liveness, vectorized
candidate pools / batch space helpers, the erf-based normal CDF/PDF, the
broadcast non-dominated sort, scheduler backpressure hooks, and per-client
wire stats surfaced through ``DispatchScheduler.stats()``."""
import threading
import zlib

import numpy as np
import pytest

from repro.core import (BayesOpt, DispatchScheduler, JClient, JConfig, JHost,
                        PAL, RandomSearch, ResultStore, SearchDriver,
                        TestConfig, transport, tpu_pod_space)
from repro.core.results import _nondominated_mask_loop, nondominated_mask
from repro.core.search.bayesopt import (GP, IncrementalGP,
                                        expected_improvement, norm_cdf,
                                        norm_pdf)
from repro.core.search.nsga2 import (_fast_nondominated_sort_loop,
                                     fast_nondominated_sort)
from repro.core.space import DesignSpace, Knob
from repro.roofline.analysis import Artifact


def _toy_objectives(space, knobs):
    x = space.encode(knobs)
    time = 2.0 - 1.2 * x[0] + 0.4 * x[1] + 0.1 * np.sin(7 * x.sum())
    power = 0.5 + 1.5 * x[0] ** 2 + 0.2 * x[2]
    return np.array([time, power])


# ---------------------------------------------------------------------------
# incremental GP: rank-append Cholesky == full refit
# ---------------------------------------------------------------------------


def test_rank_append_matches_full_refit_over_random_history():
    """Appends of mixed block sizes — including ones that cross the
    amortized-doubling capacity boundaries (16, 32, 64) — must predict
    identically (mean and variance) to a from-scratch factorisation."""
    rng = np.random.default_rng(0)
    inc = IncrementalGP()
    xs = np.zeros((0, 5))
    for step in (1, 1, 3, 1, 10, 1, 2, 17, 1, 31):
        xn = rng.random((step, 5))
        xs = np.vstack([xs, xn])
        inc.observe(xn)
        assert len(inc) == len(xs)
        y = rng.random(len(xs))
        ref = GP().fit(xs, y)
        inc.fit_y(y)
        q = rng.random((7, 5))
        mu_r, sig_r = ref.predict(q)
        mu_i, sig_i = inc.predict(q)
        np.testing.assert_allclose(mu_i, mu_r, atol=1e-8)
        np.testing.assert_allclose(sig_i, sig_r, atol=1e-8)
    assert inc._cap >= len(xs)          # grew through several doublings


def test_rank_append_kernel_matrix_grows_in_place():
    rng = np.random.default_rng(1)
    inc = IncrementalGP()
    xs = rng.random((20, 3))
    inc.observe(xs[:12]).observe(xs[12:])
    n = len(inc)
    expect = inc._k(xs, xs) + inc.noise * np.eye(n)
    np.testing.assert_allclose(inc._kb[:n, :n], expect, atol=1e-12)
    # the maintained explicit inverse really is L⁻¹
    np.testing.assert_allclose(inc._li @ inc._l, np.eye(n), atol=1e-8)


def test_fit_y_multi_matches_per_objective_fits():
    rng = np.random.default_rng(2)
    xs = rng.random((30, 4))
    Y = rng.random((30, 3))
    q = rng.random((9, 4))
    inc = IncrementalGP().fit_x(xs)
    mu_m, sig_m = inc.fit_y_multi(Y).predict_multi(q)
    mu_mean = inc.predict_mean_multi(q)
    for j in range(Y.shape[1]):
        mu_j, sig_j = inc.fit_y(Y[:, j]).predict(q)
        np.testing.assert_allclose(mu_m[:, j], mu_j, atol=1e-10)
        np.testing.assert_allclose(sig_m[:, j], sig_j, atol=1e-10)
        np.testing.assert_allclose(mu_mean[:, j], mu_j, atol=1e-10)


def test_bayesopt_incremental_picks_match_refit():
    """Same seed, same toy problem: the cached-factor path must pick the
    same configs as the per-ask refit path (fp round-off must not flip
    the EHVI ranking on this deterministic problem)."""
    space = tpu_pod_space(n_chips=256)
    seqs = {}
    for mode in ("incremental", "refit"):
        algo = BayesOpt(space, seed=3, n_init=6, pool_size=64,
                        strategy="ehvi", gp_mode=mode)
        seq = []
        for _ in range(35):
            c = algo.ask(1)[0]
            algo.tell(c, _toy_objectives(space, c))
            seq.append(c)
        seqs[mode] = seq
    assert seqs["incremental"] == seqs["refit"]


def test_maintained_front_stays_bounded_under_duplicate_tells():
    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=0, n_init=2, strategy="ehvi")
    c = space.sample(np.random.default_rng(0))
    for _ in range(10):
        algo.tell(c, np.array([1.0, 2.0]))     # identical nondominated y
    assert len(algo._front_y) == 1
    algo.tell(c, np.array([0.5, 1.0]))         # dominates: replaces
    np.testing.assert_array_equal(algo._front_y, [[0.5, 1.0]])
    algo.tell(c, np.array([0.4, 1.5]))         # incomparable: joins front
    assert len(algo._front_y) == 2


def test_pal_runs_in_both_gp_modes():
    space = tpu_pod_space(n_chips=256)
    for mode in ("incremental", "refit"):
        algo = PAL(space, seed=3, n_init=6, pool_size=64, gp_mode=mode)
        for _ in range(20):
            c = algo.ask(1)[0]
            algo.tell(c, _toy_objectives(space, c))
        assert len(algo.history_x) == 20


# ---------------------------------------------------------------------------
# SearchDriver
# ---------------------------------------------------------------------------


def test_sync_driver_is_bit_identical_to_bare_algorithm():
    space = tpu_pod_space(n_chips=256)
    bare = BayesOpt(space, seed=5, n_init=6, pool_size=64, strategy="ehvi")
    wrapped = SearchDriver(
        BayesOpt(space, seed=5, n_init=6, pool_size=64, strategy="ehvi"),
        mode="sync")
    for _ in range(8):
        a, b = bare.ask(4), wrapped.ask(4)
        assert a == b
        for c in a:
            y = _toy_objectives(space, c)
            bare.tell(c, y)
            wrapped.tell(c, y)


def test_async_driver_delivers_and_folds_tells():
    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=7, n_init=6, pool_size=64, strategy="ehvi")
    with SearchDriver(algo, mode="async", round_size=8) as drv:
        got = []
        for _ in range(10):
            picks = drv.ask(3)          # blocking form always yields n
            assert len(picks) == 3
            for c in picks:
                drv.tell(c, _toy_objectives(space, c))
                got.append(c)
        s = drv.stats()
    assert s["precomputed"] >= len(got)
    assert s["tells_folded"] + s["pending_tells"] == len(got)
    # model-based dedupe survived the driver: no repeated configs
    keys = [tuple(sorted((k, str(v)) for k, v in c.items())) for c in got]
    assert len(set(keys)) == len(keys)


def test_async_driver_poll_ask_does_not_block_without_need():
    space = tpu_pod_space(n_chips=256)
    drv = SearchDriver(RandomSearch(space, seed=0), mode="async",
                       round_size=4)
    try:
        out = drv.poll_ask(2, need=False)    # may be empty, must not hang
        assert isinstance(out, list) and len(out) <= 2
        assert len(drv.ask(5)) == 5          # blocking form fills up
    finally:
        drv.close()


def test_async_driver_surfaces_worker_exception():
    class Exploding:
        def ask(self, n):
            raise ValueError("kaboom")

        def tell(self, knobs, y):
            pass

    drv = SearchDriver(Exploding(), mode="async")
    with pytest.raises(RuntimeError, match="search worker died"):
        drv.ask(1)
    drv.close()


def test_driver_rejects_unknown_mode():
    with pytest.raises(ValueError):
        SearchDriver(RandomSearch(tpu_pod_space(n_chips=256)), mode="turbo")


# ---------------------------------------------------------------------------
# vectorized pools + batch space helpers
# ---------------------------------------------------------------------------


def test_sample_batch_and_encode_batch_match_scalar_paths():
    space = tpu_pod_space(n_chips=256)
    rng = np.random.default_rng(0)
    cfgs = space.sample_batch(rng, 50)
    assert len(cfgs) == 50
    for c in cfgs:
        for k in space.knobs:
            assert c[k.name] in k.values
    enc = space.encode_batch(cfgs)
    np.testing.assert_array_equal(enc, np.stack([space.encode(c)
                                                 for c in cfgs]))
    idx = space.index_encode_batch(cfgs)
    assert space.index_decode_batch(idx) == cfgs


def test_fresh_pool_distinct_and_excludes():
    space = tpu_pod_space(n_chips=256)
    algo = RandomSearch(space, seed=0)
    banned = {algo._flat_key(space.sample(np.random.default_rng(9)))
              for _ in range(5)}
    idx, coords, flats = algo._fresh_pool(100, exclude=banned)
    assert len(idx) == len(coords) == len(flats) == 100
    assert len(set(flats.tolist())) == 100                 # distinct
    assert not (set(flats.tolist()) & banned)              # excluded
    np.testing.assert_array_equal(
        coords, np.stack([space.encode(c)
                          for c in space.index_decode_batch(idx)]))


def test_fresh_pool_partial_on_exhausted_space():
    tiny = DesignSpace([Knob("a", (1, 2)), Knob("b", (3, 4))])   # 4 configs
    algo = RandomSearch(tiny, seed=0)
    idx, coords, flats = algo._fresh_pool(50)              # > space size
    assert 1 <= len(idx) <= 4
    assert len(set(flats.tolist())) == len(flats)


# ---------------------------------------------------------------------------
# erf-based normal (no scipy on the ask path)
# ---------------------------------------------------------------------------


def test_norm_cdf_pdf_basics():
    z = np.linspace(-8, 8, 1001)
    c = norm_cdf(z)
    assert np.all(np.diff(c) >= 0)                         # monotone
    np.testing.assert_allclose(c + norm_cdf(-z), 1.0, atol=2e-7)
    assert norm_cdf(np.array([0.0]))[0] == pytest.approx(0.5, abs=1e-7)
    assert norm_pdf(np.array([0.0]))[0] == pytest.approx(
        1.0 / np.sqrt(2 * np.pi))


def test_norm_and_ei_match_scipy_when_available():
    scipy_stats = pytest.importorskip("scipy.stats")
    z = np.linspace(-8, 8, 2001)
    np.testing.assert_allclose(norm_cdf(z), scipy_stats.norm.cdf(z),
                               atol=2e-7)
    np.testing.assert_allclose(norm_pdf(z), scipy_stats.norm.pdf(z),
                               atol=1e-12)
    rng = np.random.default_rng(0)
    mu, sig = rng.normal(size=200), rng.random(200) + 0.05
    best = 0.3
    zs = (best - mu) / sig
    ref = (best - mu) * scipy_stats.norm.cdf(zs) + sig * scipy_stats.norm.pdf(zs)
    np.testing.assert_allclose(expected_improvement(mu, sig, best), ref,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# broadcast non-dominated sort / mask
# ---------------------------------------------------------------------------


def test_fast_nondominated_sort_matches_loop_reference():
    rng = np.random.default_rng(0)
    for k in (2, 3):
        ys = rng.random((60, k))
        ys[7] = ys[31]                                     # exact tie
        fast = fast_nondominated_sort(ys)
        slow = _fast_nondominated_sort_loop(ys)
        assert len(fast) == len(slow)
        for a, b in zip(fast, slow):
            np.testing.assert_array_equal(a, b)
    assert fast_nondominated_sort(np.zeros((0, 2))) == []


def test_nondominated_mask_matches_loop_reference():
    rng = np.random.default_rng(1)
    for n in (1, 17, 60, 700):                             # crosses block size
        ys = rng.random((n, 2))
        np.testing.assert_array_equal(nondominated_mask(ys),
                                      _nondominated_mask_loop(ys))


# ---------------------------------------------------------------------------
# scheduler backpressure hooks
# ---------------------------------------------------------------------------


def test_want_lookahead_adds_chunks_for_healthy_clients_only():
    s = DispatchScheduler([0, 1], policy="pipelined", batch_size=5,
                          clock=lambda: 0.0)
    assert s.want() == 20                   # 2 clients x depth 2 x 5
    assert s.want(lookahead=1) == 30        # +1 chunk per healthy client
    s.slots[1].quarantined = True
    assert s.want(lookahead=1) == 15


def test_busy_reflects_pending_and_inflight():
    s = DispatchScheduler([0], policy="eager", batch_size=2,
                          clock=lambda: 0.0)
    assert not s.busy()
    s.submit(TestConfig(0, "a", "s", {"x": 1}))
    assert s.busy()                         # pending counts
    s.next_dispatches()
    assert s.busy()                         # now inflight
    s.on_result({"config_id": 0, "status": "ok", "client_id": 0,
                 "metrics": {}})
    s.submit(TestConfig(1, "a", "s", {"x": 2}))
    s.next_dispatches()
    assert s.busy()


# ---------------------------------------------------------------------------
# wire stats -> DispatchScheduler.stats()
# ---------------------------------------------------------------------------


def test_host_transport_counts_wire_bytes_per_client():
    pair = transport.LoopbackPair(2, codec="binary")
    host, c0 = pair.host(), pair.client(0)
    host.push_many(0, [{"cmd": "x", "config_id": i, "v": float(i)}
                       for i in range(4)])
    assert len(c0.pull_many(1.0)) == 4
    c0.push_many([{"config_id": i, "client_id": 0,
                   "metrics": {"time_s": 1.0}} for i in range(4)])
    assert len(host.pull_many(1.0)) == 4
    w = host.wire_summary()
    assert w["codec"] == "binary"
    assert w["wire_out_frames"] == 1 and w["wire_in_frames"] == 1
    assert w["wire_out_mb"] > 0 and w["wire_in_mb"] > 0
    assert w["wire_per_client"][0]["out_kb"] > 0
    assert w["wire_per_client"][0]["in_kb"] > 0            # attributed


def test_scheduler_stats_merges_wire_summary():
    s = DispatchScheduler([0], batch_size=1, clock=lambda: 0.0)
    assert "wire_out_mb" not in s.stats()
    s.wire_stats_fn = lambda: {"wire_out_mb": 1.5, "wire_in_mb": 0.5,
                               "codec": "json"}
    merged = s.stats()
    assert merged["wire_out_mb"] == 1.5 and merged["codec"] == "json"
    s.wire_stats_fn = lambda: (_ for _ in ()).throw(RuntimeError("boom"))
    assert "pending" in s.stats()           # stats never raises


# ---------------------------------------------------------------------------
# end-to-end: async SearchDriver through the JHost loop
# ---------------------------------------------------------------------------


def _toy_build(jc):
    def build(tc):
        h = zlib.crc32(repr(jc.cache_key(tc)).encode()) % 7 + 1
        art = Artifact(flops_per_device=5e12 * h, bytes_per_device=2e10,
                       wire_bytes_per_device=1e8, collectives={},
                       arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                       output_bytes=10 ** 6, n_devices=256)
        return art, {}
    return build


@pytest.mark.parametrize("driver_mode", ["sync", "async"])
def test_jhost_explore_with_search_driver(driver_mode):
    space = tpu_pod_space(n_chips=256)
    jc = JConfig(space, n_chips=256)
    pair = transport.LoopbackPair(2)
    for i in range(2):
        cl = JClient(jc, _toy_build(jc), transport=pair.client(i),
                     client_id=i, cache_size=64)
        threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.01),
                         daemon=True).start()
    host = JHost(pair.host(), ResultStore(), timeout_s=60.0, poll_s=0.01)
    algo = BayesOpt(space, seed=0, n_init=8, pool_size=64, strategy="ehvi")
    with SearchDriver(algo, mode=driver_mode) as search:
        store = host.explore(search, "toy", "s", 40,
                             batch_size=5, dispatch="pipelined")
    host.stop_clients()
    assert len(store.records) == 40
    assert all(r.status == "ok" for r in store.records)
    # every evaluated config was a distinct point of the space
    ids = {r.config_id for r in store.records}
    assert len(ids) == 40
    # wire stats flowed into the scheduler stats
    s = host.scheduler.stats()
    assert s["wire_out_mb"] > 0 and s["wire_in_mb"] > 0


# ---------------------------------------------------------------------------
# hyperparameter refresh schedule (numpy modes)
# ---------------------------------------------------------------------------


def _drive_linear(algo, space, n):
    for _ in range(n):
        c = algo.ask(1)[0]
        x = space.encode(c)
        algo.tell(c, np.array([x[0] + 0.5 * x[1], 1.0 - x[0] + 0.3 * x[2]]))


def test_hyper_refresh_fires_on_schedule_incremental():
    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=3, n_init=6, pool_size=64,
                    strategy="ehvi", hyper_refresh_every=10)
    _drive_linear(algo, space, 30)
    assert algo.n_hyper_refreshes >= 2
    # linear targets: log-ML prefers a larger lengthscale than the default
    assert algo._gp.ls > 0.3


def test_hyper_refresh_refit_mode_carries_tuned_lengthscale():
    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=3, n_init=6, pool_size=64,
                    strategy="parego", gp_mode="refit",
                    hyper_refresh_every=10)
    _drive_linear(algo, space, 30)
    assert algo.n_hyper_refreshes >= 2
    assert algo._ls > 0.3          # future per-ask refits use the tuned value


def test_hyper_refresh_disabled_by_default():
    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=3, n_init=6, pool_size=64, strategy="ehvi")
    _drive_linear(algo, space, 20)
    assert algo.n_hyper_refreshes == 0 and algo._gp.ls == 0.3


def test_set_lengthscale_matches_fresh_fit():
    """In-place lengthscale adoption (kernel recompute + one refactor on
    the existing buffers) must equal a from-scratch GP at the new value."""
    rng = np.random.default_rng(0)
    xs = rng.random((30, 4))
    y = rng.random(30)
    q = rng.random((7, 4))
    inc = IncrementalGP().fit_x(xs)
    inc.set_lengthscale(0.7)
    mu_i, sig_i = inc.fit_y(y).predict(q)
    ref = GP(lengthscale=0.7).fit(xs, y)
    mu_r, sig_r = ref.predict(q)
    np.testing.assert_allclose(mu_i, mu_r, atol=1e-9)
    np.testing.assert_allclose(sig_i, sig_r, atol=1e-9)
    # appends after the retune keep the new lengthscale
    xn = rng.random((3, 4))
    inc.observe(xn)
    ref2 = GP(lengthscale=0.7).fit(np.vstack([xs, xn]), np.concatenate(
        [y, rng.random(3)]))
    assert inc.ls == 0.7 and len(inc) == 33


def test_tune_lengthscale_deterministic_and_bounded():
    from repro.core.search.bayesopt import tune_lengthscale
    rng = np.random.default_rng(1)
    xs = rng.random((120, 4))
    y = xs[:, 0] + 0.5 * xs[:, 1]              # smooth: larger ls wins
    a = tune_lengthscale(xs, y, current=0.3)
    b = tune_lengthscale(xs, y, current=0.3)
    assert a == b and a > 0.3
    # too little data: incumbent unchanged
    assert tune_lengthscale(xs[:2], y[:2], current=0.3) == 0.3


# ---------------------------------------------------------------------------
# shadow-aware candidate pools (residency biasing)
# ---------------------------------------------------------------------------


def _sw_fp(space):
    def fp(knobs):
        return tuple((k.name, knobs[k.name]) for k in space.knobs
                     if k.kind == "sw")
    return fp


def test_residency_bias_reduces_unique_fresh_fingerprints():
    """Same seed, same objectives: a searcher biased toward a small
    resident set must dispatch strictly fewer unique sw fingerprints than
    its unbiased clone."""
    space = tpu_pod_space(n_chips=256)
    fp = _sw_fp(space)

    def run(biased):
        algo = BayesOpt(space, seed=5, n_init=6, pool_size=64,
                        strategy="ehvi")
        algo.set_sw_fingerprint_fn(fp)
        fps = set()
        while len(algo.history_x) < 80:
            for c in algo.ask(2):
                x = space.encode(c)
                algo.tell(c, np.array([np.sin(3 * x[0]) + x[1],
                                       x[0] ** 2 + np.cos(2 * x[1])]))
                fps.add(fp(c))
            if biased and len(algo.history_x) >= 20:
                algo.note_residency(
                    {fp(k) for k in algo.history_x[:10]})
        return fps

    assert len(run(True)) < len(run(False))


def test_residency_noop_without_fingerprint_fn():
    """No fingerprint fn installed: note_residency alone must not change
    the rng stream or the picks (bit-identical to an untouched clone)."""
    space = tpu_pod_space(n_chips=256)
    a = BayesOpt(space, seed=7, n_init=4, pool_size=32, strategy="ehvi")
    b = BayesOpt(space, seed=7, n_init=4, pool_size=32, strategy="ehvi")
    a.note_residency({("dtype", "bfloat16")})
    for _ in range(15):
        ca, cb = a.ask(1)[0], b.ask(1)[0]
        assert ca == cb
        xa = space.encode(ca)
        y = np.array([xa[0], 1.0 - xa[0]])
        a.tell(ca, y)
        b.tell(cb, y)


def test_driver_forwards_residency_to_algorithm():
    import time

    space = tpu_pod_space(n_chips=256)
    fp = _sw_fp(space)
    for mode in ("sync", "async"):
        algo = BayesOpt(space, seed=0, n_init=2, pool_size=16,
                        strategy="ehvi")
        with SearchDriver(algo, mode=mode) as drv:
            drv.set_sw_fingerprint_fn(fp)
            c = space.sample(np.random.default_rng(0))
            drv.note_residency({fp(c)})
            drv.tell(c, np.array([1.0, 2.0]))
            drv.ask(1)
            # async: the first buffered round may predate the updates — the
            # worker folds them at its next wake, so poll briefly
            for _ in range(500):
                if algo._resident_fps and algo._sw_fp_fn is fp:
                    break
                drv.ask(1)
                time.sleep(0.005)
            assert algo._sw_fp_fn is fp
            assert algo._resident_fps == {fp(c)}
            assert fp(c) in algo._fp_to_sw


def test_jhost_plumbs_residency_into_search():
    space = tpu_pod_space(n_chips=256)
    jc = JConfig(space, n_chips=256)
    pair = transport.LoopbackPair(2)
    for i in range(2):
        cl = JClient(jc, _toy_build(jc), transport=pair.client(i),
                     client_id=i, cache_size=64)
        threading.Thread(target=cl.serve, kwargs=dict(poll_s=0.01),
                         daemon=True).start()
    host = JHost(pair.host(), ResultStore(), timeout_s=60.0, poll_s=0.01)
    algo = BayesOpt(space, seed=0, n_init=8, pool_size=64, strategy="ehvi")
    store = host.explore(algo, "toy", "s", 40, batch_size=5,
                         dispatch="pipelined", fingerprint_fn=jc.cache_key)
    host.stop_clients()
    assert len(store.records) == 40
    assert algo._sw_fp_fn is not None
    assert algo._fp_to_sw                  # tells recorded fp -> sw combos
    assert algo._resident_fps              # fleet residency reached the algo
