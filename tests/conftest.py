"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests run in subprocesses with their own env.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with N forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)
