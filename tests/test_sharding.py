"""Distribution: sharding rules, multi-device train/decode lowering, pipeline
parallelism, int8 collective compression.  Multi-device cases run in
subprocesses with forced host device counts (the main process must keep 1
device for the smoke tests)."""
import numpy as np
import pytest

from tests.conftest import run_with_devices


def test_param_spec_rules_single_device():
    """Spec shapes are rank-correct and divisibility-safe (pure logic)."""
    import jax

    from repro.configs import get_arch
    from repro.models import BuildFlags, Model

    code_mesh = None  # single-device policy still yields valid specs
    from repro.parallel.sharding import ShardingPolicy
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    policy = ShardingPolicy(mesh)
    model = Model(get_arch("deepseek-moe-16b"), BuildFlags())
    shapes = model.init_shapes()
    specs = policy.param_specs_tree(shapes)
    flat_shapes = jax.tree.leaves(shapes)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or type(x).__name__ == "PartitionSpec")
    assert len(flat_shapes) == len(flat_specs)
    for shp, spec in zip(flat_shapes, flat_specs):
        assert len(spec) <= len(shp.shape)
        for dim, axes in zip(shp.shape, tuple(spec) + (None,) * 8):
            if axes is None:
                continue
            size = np.prod([mesh.shape[a] for a in
                            ((axes,) if isinstance(axes, str) else axes)])
            assert dim % size == 0, (shp.shape, tuple(spec))


def test_train_step_lowers_on_2x4_mesh():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.configs import get_arch, reduced, ShapeConfig
from repro.launch.build import build_cell
from repro.launch.mesh import make_mesh_dp_tp
from repro.models import BuildFlags

mesh = make_mesh_dp_tp(2, 4)
for name in ["tinyllama-1.1b", "deepseek-moe-16b", "jamba-v0.1-52b", "mamba2-780m"]:
    arch = reduced(get_arch(name), d_model=64, head_dim=16)
    shape = ShapeConfig("t", "train", 32, 4)
    cell = build_cell(arch, shape, mesh, BuildFlags(dtype="float32", sp=True))
    assert cell.compiled is not None
    print("LOWER_OK", name)
""", n_devices=8)
    assert out.count("LOWER_OK") == 4


def test_sharded_train_matches_single_device():
    """The same train step on a (2,4) mesh and on 1 device gives the same
    loss trajectory — SPMD correctness end-to-end."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, reduced
from repro.data import DataConfig, SyntheticLM, device_put_batch
from repro.models import BuildFlags, Model
from repro.parallel.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh_dp_tp
from repro.train import TrainStepConfig, adamw, cosine_schedule, init_train_state, make_train_step

arch = reduced(get_arch("tinyllama-1.1b"))
def run(policy):
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=policy is not None), policy)
    opt = adamw(cosine_schedule(1e-3, 2, 20))
    state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(arch, DataConfig(batch=4, seq_len=32, seed=1))
    losses = []
    for i in range(4):
        state, m = step(state, device_put_batch(data.batch(i), policy))
        losses.append(float(m["loss"]))
    return losses

mesh = make_mesh_dp_tp(2, 4)
l_sharded = run(ShardingPolicy(mesh))
l_single = run(None)
np.testing.assert_allclose(l_sharded, l_single, rtol=2e-4)
print("SPMD_MATCH", l_sharded)
"""
    out = run_with_devices(code, n_devices=8)
    assert "SPMD_MATCH" in out


def test_decode_cache_seq_sharding():
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.configs import get_arch, reduced, ShapeConfig
from repro.launch.build import build_cell
from repro.launch.mesh import make_mesh_dp_tp
from repro.models import BuildFlags

mesh = make_mesh_dp_tp(2, 4)
arch = reduced(get_arch("glm4-9b"), d_model=64, head_dim=16)
shape = ShapeConfig("d", "decode", 64, 4)   # 64-token cache, batch 4
cell = build_cell(arch, shape, mesh, BuildFlags(dtype="float32"))
assert cell.compiled is not None
# batch=1 long-context path: cache seq must shard over data+model
shape1 = ShapeConfig("d1", "decode", 64, 1)
cell1 = build_cell(arch, shape1, mesh, BuildFlags(dtype="float32"))
assert cell1.compiled is not None
print("DECODE_OK")
""", n_devices=8)
    assert "DECODE_OK" in out


def test_pipeline_parallel_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh_dp_tp
from repro.parallel.pipeline import pipeline_apply, bubble_fraction

from repro.launch.mesh import _make
mesh = _make((4,), ("pipe",))   # jax<0.5-compatible make_mesh
n_stages, n_micro, mb, d = 4, 8, 2, 16

def stage_fn(w, x):
    return jnp.tanh(x @ w)

key = jax.random.key(0)
ws = jax.random.normal(key, (n_stages, d, d)) * 0.5
xs = jax.random.normal(jax.random.key(1), (n_micro, mb, d))

out = pipeline_apply(mesh, "pipe", stage_fn, ws, xs)

# sequential reference: each microbatch through all stages
ref = xs
for s in range(n_stages):
    ref = jnp.tanh(ref @ ws[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print("PIPELINE_OK")
"""
    out = run_with_devices(code, n_devices=4)
    assert "PIPELINE_OK" in out


def test_psum_int8_close_to_exact():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.parallel.compress import psum_int8

from repro.launch.mesh import _make
mesh = _make((8,), ("data",))   # jax<0.5-compatible make_mesh
x = jax.random.normal(jax.random.key(0), (8, 128))

def f(x):
    return psum_int8(x[0], "data")

def g(x):
    return jax.lax.psum(x[0], "data")

fa = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P())
ga = shard_map(g, mesh=mesh, in_specs=P("data"), out_specs=P())
approx, exact = fa(x), ga(x)
err = np.abs(np.asarray(approx) - np.asarray(exact)).max()
scale = np.abs(np.asarray(exact)).max()
assert err < 0.1 * scale, (err, scale)
print("PSUM_INT8_OK", err / scale)
"""
    out = run_with_devices(code, n_devices=8)
    assert "PSUM_INT8_OK" in out


def test_grouped_moe_matches_ungrouped():
    """Group-local MoE dispatch (g=dp) equals the g=1 reference when capacity
    is ample (no drops) — the §Perf A optimization must not change the math."""
    code = """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch, reduced
from repro.models import BuildFlags, Model
from repro.parallel.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh_dp_tp
from repro.data import DataConfig, SyntheticLM, device_put_batch

arch = dataclasses.replace(reduced(get_arch("deepseek-moe-16b")),
                           capacity_factor=4.0)   # no drops
batch = SyntheticLM(arch, DataConfig(batch=4, seq_len=16, seed=2)).batch(0)

mesh = make_mesh_dp_tp(2, 4)
policy = ShardingPolicy(mesh, sp=False, fsdp=False)
m_sharded = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False,
                                   fsdp=False), policy)
m_single = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
params = m_single.init(jax.random.key(0))
l1, _ = m_single.loss_fn(params, device_put_batch(batch))
l2, _ = m_sharded.loss_fn(params, device_put_batch(batch, policy))
np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
print("GROUPED_MOE_OK", float(l1), float(l2))
"""
    out = run_with_devices(code, n_devices=8)
    assert "GROUPED_MOE_OK" in out
