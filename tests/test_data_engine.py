"""Data pipeline determinism + serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import BuildFlags, Model
from repro.serve import Engine


def test_data_deterministic_per_step():
    arch = reduced(get_arch("tinyllama-1.1b"))
    d1 = SyntheticLM(arch, DataConfig(batch=4, seq_len=32, seed=9))
    d2 = SyntheticLM(arch, DataConfig(batch=4, seq_len=32, seed=9))
    for step in (0, 5, 17):
        b1, b2 = d1.batch(step), d2.batch(step)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])
    # different steps/seeds differ
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])
    d3 = SyntheticLM(arch, DataConfig(batch=4, seq_len=32, seed=10))
    assert not np.array_equal(d1.batch(0)["tokens"], d3.batch(0)["tokens"])


def test_data_zipf_head_heavy():
    arch = reduced(get_arch("tinyllama-1.1b"))
    d = SyntheticLM(arch, DataConfig(batch=16, seq_len=128, seed=0))
    toks = d.batch(0)["tokens"].ravel()
    # token 0 (rank 1) must be much more frequent than the median token
    assert (toks == 0).mean() > 5.0 / arch.vocab_size


def test_vlm_batch_shapes():
    arch = reduced(get_arch("internvl2-2b"))
    d = SyntheticLM(arch, DataConfig(batch=2, seq_len=16, seed=0))
    b = d.batch(0)
    f = arch.n_frontend_tokens
    assert b["image_embeds"].shape == (2, f, arch.d_model)
    assert b["tokens"].shape == (2, 16 - f)
    assert b["labels"].shape == (2, 16)


def _engine():
    arch = reduced(get_arch("tinyllama-1.1b"))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    params = model.init(jax.random.key(0))
    return arch, model, params


def test_engine_greedy_deterministic():
    arch, model, params = _engine()
    eng = Engine(model, params, max_len=32, donate=False)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, arch.vocab_size, (3, 8)), jnp.int32)}
    r1 = eng.generate(batch, 10)
    r2 = eng.generate(batch, 10)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (3, 10)


def test_engine_matches_manual_decode_loop():
    """Engine's scan-based loop == hand-rolled prefill + decode_step loop."""
    arch, model, params = _engine()
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, arch.vocab_size, (2, 6)), jnp.int32)
    n_gen, max_len = 5, 24
    eng = Engine(model, params, max_len=max_len, donate=False)
    got = eng.generate({"tokens": toks}, n_gen).tokens

    logits, caches = model.prefill(params, {"tokens": toks})
    def grow(c):
        if c.ndim >= 3 and c.shape[-3] == 6:
            w = [(0, 0)] * c.ndim
            w[-3] = (0, max_len - 6)
            return jnp.pad(c, w)
        return c
    caches = jax.tree.map(grow, caches)
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out.append(tok)
    for i in range(n_gen - 1):
        logits, caches = model.decode_step(params, tok, caches, 6 + i)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out.append(tok)
    want = np.concatenate([np.asarray(t) for t in out], axis=1)
    np.testing.assert_array_equal(got, want)
