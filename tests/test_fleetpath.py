"""Compile-affinity fleet scheduling + persistent artifact cache (PR 4):
CacheShadow LRU-fidelity vs a live JClient trace (property test), affinity
placement under quarantine/failover, speculative re-dispatch winner/loser
accounting, the persistent cache tier across client restarts, pipeline
depth >2, the SearchDriver staleness bound, and PAL's mean-only path."""
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container has no hypothesis
    from _propcheck import given, settings, st

from repro.core import (DispatchScheduler, JClient, JConfig, JHost, PAL,
                        ResultStore, SearchDriver, TestConfig, transport)
from repro.core.scheduler import CacheShadow
from repro.core.space import DesignSpace, KIND_HW, KIND_SW, Knob
from repro.core.transport import unframe_batch
from repro.roofline.analysis import Artifact


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def toy_artifact(f=5e12, n_dev=256):
    return Artifact(flops_per_device=f, bytes_per_device=2e10,
                    wire_bytes_per_device=1e8, collectives={},
                    arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                    output_bytes=10 ** 6, n_devices=n_dev)


def small_space(n_fps=4):
    return DesignSpace([
        Knob("clock_scale", (0.5, 1.0), KIND_HW),
        Knob("blk", tuple(range(n_fps)), KIND_SW),
    ])


def counting_build(jc, cost_s=0.0):
    calls = []

    def build(tc):
        if cost_s:
            time.sleep(cost_s)
        calls.append(jc.cache_key(tc))
        h = hash(jc.cache_key(tc)) % 7 + 1
        return toy_artifact(5e12 * h), {"decode_artifact": toy_artifact(1e11 * h),
                                        "n_decode_tokens": 10}

    return build, calls


# scheduler-level helpers: configs whose fingerprint is just a knob value
def ftc(i, fp):
    return TestConfig(i, "a", "s", {"x": i, "sw": fp})


def fp_of(tc):
    return tc.knobs["sw"]


def ok(cid, client, cached=False, **extra):
    msg = {"config_id": cid, "status": "ok", "client_id": client,
           "metrics": {"time_s": 1.0}, "cached": cached, "wall_s": 0.0}
    msg.update(extra)
    return msg


def answer(sched, client, tcs, **extra):
    for t in tcs:
        sched.on_result(ok(t.config_id, client, **extra))


def affinity_sched(clients=(0, 1), clk=None, **kw):
    kw.setdefault("policy", "pipelined")
    kw.setdefault("batch_size", 2)
    kw.setdefault("affinity", "prefer")
    return DispatchScheduler(clients, fingerprint_fn=fp_of,
                             clock=clk or FakeClock(), **kw)


# ---------------------------------------------------------------------------
# CacheShadow: the host's model must track a real JClient LRU exactly
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                max_size=60),
       st.integers(min_value=1, max_value=6))
def test_shadow_matches_jclient_lru_trace(fp_seq, capacity):
    """Drive a live JClient and a CacheShadow with the same fingerprint
    sequence: residency verdicts, resident sets, LRU order, and eviction
    counts must all agree at every step."""
    space = small_space(n_fps=10)
    jc = JConfig(space, n_chips=8)
    build, _ = counting_build(jc)
    client = JClient(jc, build, cache_size=capacity)
    shadow = CacheShadow(capacity)
    for i, fp in enumerate(fp_seq):
        tc = TestConfig(i, "a", "s", {"clock_scale": 1.0, "blk": fp})
        key = jc.cache_key(tc)
        was_resident = key in client._cache
        client._artifact(key, tc)
        assert shadow.touch(key) == was_resident
        assert shadow.keys() == list(client._cache)      # same LRU order
        assert shadow.evictions == client._cache_evictions


def test_shadow_resync_trims_and_retunes():
    shadow = CacheShadow(8)
    for fp in "abcde":
        shadow.touch(fp)
    shadow.resync(currsize=3, maxsize=3)
    assert shadow.capacity == 3
    assert shadow.keys() == ["c", "d", "e"]              # LRU end trimmed
    shadow.touch("f")                                    # evicts at new cap
    assert len(shadow) == 3 and "c" not in shadow


def test_shadow_resync_drops_optimistic_marks_before_confirmed():
    shadow = CacheShadow(8)
    shadow.touch("a")                         # confirmed from results
    shadow.touch("b")
    shadow.touch("x", confirmed=False)        # optimistic dispatch marks
    shadow.touch("y", confirmed=False)
    shadow.resync(currsize=2, maxsize=8)
    # the client says it holds 2: the unconfirmed marks (e.g. a failed
    # chunk's groups) are the suspects, not the known-resident entries
    assert shadow.keys() == ["a", "b"]
    shadow.touch("x", confirmed=False)
    shadow.resync(currsize=2, maxsize=8)
    assert shadow.keys() == ["a", "b"]


# ---------------------------------------------------------------------------
# affinity placement
# ---------------------------------------------------------------------------


def test_unclaimed_groups_spread_one_per_chunk():
    s = affinity_sched(batch_size=4)
    for i, fp in enumerate("AABB"):
        s.submit(ftc(i, fp))
    d = s.next_dispatches()
    # two fresh compile groups -> two single-fingerprint chunks, one per
    # client, even though either chunk had room for both groups
    assert len(d) == 2
    placed = {cfgs[0].knobs["sw"]: c for c, cfgs in d}
    assert set(placed) == {"A", "B"}
    assert len({c for c in placed.values()}) == 2
    for _, cfgs in d:
        assert len({t.knobs["sw"] for t in cfgs}) == 1


def test_affinity_routes_to_resident_client():
    clk = FakeClock()
    s = affinity_sched(clk=clk, batch_size=2)
    for i, fp in enumerate("AABB"):
        s.submit(ftc(i, fp))
    first = dict()
    for c, cfgs in s.next_dispatches():
        first[cfgs[0].knobs["sw"]] = c
        answer(s, c, cfgs)
    # new work for both fingerprints goes home, regardless of submit order
    s.submit(ftc(10, "B"))
    s.submit(ftc(11, "A"))
    s.submit(ftc(12, "B"))
    homes = {cfgs[0].knobs["sw"]: c for c, cfgs in s.next_dispatches()}
    assert homes["A"] == first["A"]
    assert homes["B"] == first["B"]


def test_resident_groups_ride_along_with_one_new_group():
    s = affinity_sched(clients=(0,), batch_size=8)
    for i, fp in enumerate("AAAA"):
        s.submit(ftc(i, fp))
    (c0, cfgs0), = s.next_dispatches()
    answer(s, c0, cfgs0)
    # A is resident; a mixed backlog packs resident A's plus exactly one
    # new group (B) into the first chunk — C waits for its own chunk
    for i, fp in enumerate("ABBC", start=10):
        s.submit(ftc(i, fp))
    d = s.next_dispatches()
    assert set(t.knobs["sw"] for t in d[0][1]) == {"A", "B"}
    assert [t.knobs["sw"] for t in d[1][1]] == ["C"]


def test_strict_waits_for_busy_home_client():
    s = affinity_sched(affinity="strict", policy="eager", batch_size=2)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()        # A claimed by client 0
    s.submit(ftc(2, "A"))
    s.submit(ftc(3, "A"))
    # client 0 is busy (eager depth-1) and client 1 is idle, but strict
    # never re-compiles a group a healthy client already owns
    assert s.next_dispatches() == []
    assert len(s.pending) == 2
    answer(s, c0, cfgs0)
    (c1, cfgs1), = s.next_dispatches()
    assert c1 == c0


def test_prefer_steals_rather_than_idle():
    s = affinity_sched(affinity="prefer", policy="eager", batch_size=2)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    s.submit(ftc(2, "A"))
    s.submit(ftc(3, "A"))
    d = s.next_dispatches()                   # the idle client takes them
    assert [c for c, _ in d] == [1 - c0]


def test_quarantine_clears_shadow_and_fails_over():
    clk = FakeClock()
    s = affinity_sched(affinity="strict", policy="eager", batch_size=2,
                       clk=clk, timeout_s=10.0, max_retries=2)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    clk.advance(25.0)                         # blow the 2-config deadline
    assert s.expire() == []                   # retries left
    assert c0 in s.quarantined
    assert len(s.slots[c0].shadow) == 0       # dead home forgets its cache
    d = s.next_dispatches()                   # strict now re-homes the group
    assert [c for c, _ in d] == [1 - c0]


def test_affinity_requires_fingerprint_fn():
    with pytest.raises(ValueError):
        DispatchScheduler([0], affinity="prefer")


# ---------------------------------------------------------------------------
# speculative re-dispatch
# ---------------------------------------------------------------------------


def spec_sched(clk, **kw):
    kw.setdefault("policy", "eager")
    kw.setdefault("batch_size", 2)
    kw.setdefault("timeout_s", 10.0)
    kw.setdefault("speculate_frac", 0.5)
    return DispatchScheduler([0, 1], fingerprint_fn=fp_of, clock=clk, **kw)


def test_mirror_dispatched_at_deadline_fraction():
    clk = FakeClock()
    s = spec_sched(clk)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    clk.advance(9.0)                          # budget 20, frac 0.5 -> at 10
    assert s.next_dispatches() == []
    clk.advance(1.5)
    d = s.next_dispatches()
    assert [c for c, _ in d] == [1 - c0]      # mirrored to the idle peer
    assert [t.config_id for t in d[0][1]] == [0, 1]
    assert s.n_speculated == 1
    assert s.next_dispatches() == []          # never mirrored twice


def test_mirror_win_cancels_primary_and_dedupes_late_answers():
    clk = FakeClock()
    s = spec_sched(clk)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    clk.advance(11.0)
    (c1, _), = s.next_dispatches()
    assert s.on_result(ok(0, c1)) is not None     # mirror answers first
    assert s.on_result(ok(1, c1)) is not None
    assert s.n_spec_wins_mirror == 1 and s.n_spec_cancelled == 1
    assert not s.chunks and not s.inflight        # both twins retired
    assert not s.slots[c0].chunks and not s.slots[c1].chunks
    # the losing primary's late answers are plain duplicates
    assert s.on_result(ok(0, c0)) is None
    assert s.on_result(ok(1, c0)) is None


def test_primary_win_cancels_mirror():
    clk = FakeClock()
    s = spec_sched(clk)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    clk.advance(11.0)
    (c1, _), = s.next_dispatches()
    answer(s, c0, cfgs0)                          # owner answers after all
    assert s.n_spec_wins_primary == 1 and s.n_spec_cancelled == 1
    assert not s.chunks and not s.slots[c1].chunks


def test_expired_primary_hands_configs_to_live_mirror():
    clk = FakeClock()
    s = spec_sched(clk, max_retries=2)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    clk.advance(11.0)
    (c1, _), = s.next_dispatches()
    clk.advance(10.0)                             # past the primary deadline
    assert s.expire() == []
    assert c0 in s.quarantined
    # nothing re-queued: the mirror already carries both configs
    assert len(s.pending) == 0
    assert all(s.inflight[c]["chunk"] in
               {cid for cid in s.chunks} for c in (0, 1))
    assert s.on_result(ok(0, c1)) is not None
    assert s.on_result(ok(1, c1)) is not None
    assert not s.chunks and not s.inflight


def test_mirror_skips_straggler_answered_configs():
    """A cid the owner still awaits but a peer already answered is neither
    re-sent to the mirror nor awaited from it — whichever side empties
    first, both slots end up free and late answers stay duplicates."""
    clk = FakeClock()
    s = spec_sched(clk)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    # a peer answers cfg 0: recorded, but the owner still owes its chunk
    assert s.on_result(ok(0, 1 - c0)) is not None
    clk.advance(11.0)
    (c1, mirrored), = s.next_dispatches()
    assert [t.config_id for t in mirrored] == [1]     # cfg 0 not re-sent
    assert s.chunks[s.slots[c1].chunks[0]].awaiting == {1}
    assert s.on_result(ok(1, c1)) is not None         # mirror answers it
    assert s.n_spec_wins_mirror == 1
    # the cancelled primary's own late answers are duplicates, and its
    # slot was freed by the cancel
    assert not s.slots[c0].chunks and not s.slots[c1].chunks
    assert s.on_result(ok(0, c0)) is None
    assert s.on_result(ok(1, c0)) is None
    assert not s.chunks and not s.inflight


def test_emptied_mirror_is_cancelled_while_primary_finishes():
    clk = FakeClock()
    s = spec_sched(clk)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    (c0, cfgs0), = s.next_dispatches()
    assert s.on_result(ok(0, 1 - c0)) is not None     # straggler answer
    clk.advance(11.0)
    (c1, mirrored), = s.next_dispatches()
    # the PRIMARY answers the mirrored config first: the mirror has
    # nothing left to wait for and must not block its slot until a
    # deadline quarantines an innocent client
    assert s.on_result(ok(1, c0)) is not None
    assert s.n_spec_cancelled == 1 and s.n_spec_wins_primary == 1
    assert not s.slots[c1].chunks
    # the owner still owes cfg 0 itself; its duplicate answer frees it
    assert s.slots[c0].chunks
    assert s.on_result(ok(0, c0)) is None
    assert not s.slots[c0].chunks and not s.chunks


def test_no_mirror_without_capacity():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="eager", batch_size=2, timeout_s=10.0,
                          speculate_frac=0.5, fingerprint_fn=fp_of, clock=clk)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "A"))
    s.next_dispatches()
    clk.advance(15.0)
    assert s.next_dispatches() == []              # nowhere to mirror to
    assert s.n_speculated == 0


# ---------------------------------------------------------------------------
# pipeline depth > 2
# ---------------------------------------------------------------------------


def test_pipeline_depth_generalizes_double_buffering():
    clk = FakeClock()
    s = DispatchScheduler([0], policy="pipelined", batch_size=2,
                          pipeline_depth=4, timeout_s=10.0, clock=clk)
    assert s.want() == 8                      # depth 4 x 2 configs
    for i in range(20):
        s.submit(ftc(i, "A"))
    d = s.next_dispatches()
    assert [len(cfgs) for _, cfgs in d] == [2, 2, 2, 2]
    assert s.next_dispatches() == []          # invariant: never deeper than 4
    # stacked deadlines: each queued chunk's clock starts at its
    # predecessor's budget end, at any depth
    deadlines = [s.chunks[c].deadline for c in s.slots[0].chunks]
    assert deadlines == [pytest.approx(20.0 * k) for k in range(1, 5)]
    answer(s, 0, d[0][1])
    assert len(s.slots[0].chunks) == 3
    assert [len(cfgs) for _, cfgs in s.next_dispatches()] == [2]


def test_pipeline_depth_validation():
    with pytest.raises(ValueError):
        DispatchScheduler([0], pipeline_depth=0)


# ---------------------------------------------------------------------------
# persistent artifact cache (cache_dir)
# ---------------------------------------------------------------------------


def test_restarted_client_rides_disk_tier(tmp_path):
    space = small_space(n_fps=3)
    jc = JConfig(space, n_chips=8)
    build, calls = counting_build(jc)
    rng = np.random.default_rng(0)
    tcs = [TestConfig(i, "a", "s", space.sample(rng)) for i in range(12)]
    unique = len({jc.cache_key(t) for t in tcs})

    c1 = JClient(jc, build, cache_size=8, cache_dir=str(tmp_path))
    res1 = c1.evaluate_batch(tcs)
    assert c1.n_compiled == unique
    assert c1.cache_info()["disk_stores"] == unique

    c2 = JClient(jc, build, cache_size=8, cache_dir=str(tmp_path))  # restart
    res2 = c2.evaluate_batch(tcs)
    assert c2.n_compiled == 0                     # every build from disk
    assert c2.cache_info()["disk_hits"] == unique
    for a, b in zip(res1, res2):
        assert a["metrics"] == b["metrics"]


def test_corrupt_disk_entry_is_a_miss(tmp_path):
    space = small_space()
    jc = JConfig(space, n_chips=8)
    build, _ = counting_build(jc)
    tc = TestConfig(0, "a", "s", {"clock_scale": 1.0, "blk": 1})
    c1 = JClient(jc, build, cache_dir=str(tmp_path))
    c1.evaluate(tc)
    path = c1._disk_path(jc.cache_key(tc))
    with open(path, "wb") as f:
        f.write(b"not a pickle")
    c2 = JClient(jc, build, cache_dir=str(tmp_path))
    assert c2.evaluate(tc)["status"] == "ok"
    assert c2.n_compiled == 1                     # rebuilt, not crashed
    assert c2.cache_info()["disk_hits"] == 0


def test_disk_tier_respects_jconfig_identity(tmp_path):
    space = small_space()
    jc8 = JConfig(space, n_chips=8)
    build, _ = counting_build(jc8)
    tc = TestConfig(0, "a", "s", {"clock_scale": 1.0, "blk": 1})
    JClient(jc8, build, cache_dir=str(tmp_path)).evaluate(tc)
    # same knobs, different fleet shape: must not be served the old artifact
    jc16 = JConfig(space, n_chips=16)
    build16, calls16 = counting_build(jc16)
    c = JClient(jc16, build16, cache_dir=str(tmp_path))
    c.evaluate(tc)
    assert c.n_compiled == 1 and len(calls16) == 1


def test_client_restart_mid_run_integration(tmp_path):
    """Host explores a sweep, the client 'process' restarts (fresh JClient,
    same --cache-dir), the host explores again: the restarted client must
    answer every group from the persistent tier without one recompile."""
    space = small_space(n_fps=4)
    jc = JConfig(space, n_chips=8)
    build, _ = counting_build(jc)
    rng = np.random.default_rng(1)
    knobs = [space.sample(rng) for _ in range(24)]
    unique = len({jc.cache_key(TestConfig(0, "a", "s", k)) for k in knobs})

    pair = transport.LoopbackPair(1)

    class Replay:
        def __init__(self, ks):
            self._k = list(ks)

        def ask(self, n):
            out, self._k = self._k[:n], self._k[n:]
            return out

        def tell(self, knobs, y):
            pass

    c1 = JClient(jc, build, transport=pair.client(0), client_id=0,
                 cache_size=8, cache_dir=str(tmp_path))
    t1 = threading.Thread(target=c1.serve, kwargs=dict(poll_s=0.01),
                          daemon=True)
    t1.start()
    host = JHost(pair.host(), ResultStore(), timeout_s=60.0, poll_s=0.01)
    host.explore(Replay(knobs), "a", "s", len(knobs), batch_size=6,
                 dispatch="pipelined", affinity="prefer",
                 fingerprint_fn=jc.cache_key)
    host.transport.push(0, {"cmd": "stop"})
    t1.join(timeout=10.0)
    assert c1.n_compiled == unique

    # restart: a brand-new client instance on the same wire + cache dir
    c2 = JClient(jc, build, transport=pair.client(0), client_id=0,
                 cache_size=8, cache_dir=str(tmp_path))
    t2 = threading.Thread(target=c2.serve, kwargs=dict(poll_s=0.01),
                          daemon=True)
    t2.start()
    store = host.explore(Replay(knobs), "a", "s", len(knobs), batch_size=6,
                         dispatch="pipelined", affinity="prefer",
                         fingerprint_fn=jc.cache_key)
    host.transport.push(0, {"cmd": "stop"})
    t2.join(timeout=10.0)
    assert c2.n_compiled == 0                     # no recompiles after restart
    assert c2.cache_info()["disk_hits"] == unique
    assert sum(1 for r in store.records if r.status == "ok") >= len(knobs)


# ---------------------------------------------------------------------------
# cache_info wire plumbing + shadow resync from replies
# ---------------------------------------------------------------------------


def test_cache_info_rides_result_frames():
    pair = transport.LoopbackPair(1)
    ct = pair.client(0)
    msgs = [ok(i, 0) for i in range(3)]
    ct.push_many(msgs, extra={"cache_info": {"currsize": 2, "maxsize": 2}})
    got = pair.host().pull_many(1.0)
    assert len(got) == 3
    assert "cache_info" not in got[0] and "cache_info" not in got[1]
    assert got[-1]["cache_info"] == {"currsize": 2, "maxsize": 2}


def test_serve_attaches_cache_info_and_scheduler_resyncs():
    space = small_space(n_fps=6)
    jc = JConfig(space, n_chips=8)
    build, _ = counting_build(jc)
    pair = transport.LoopbackPair(1)
    client = JClient(jc, build, transport=pair.client(0), client_id=0,
                     cache_size=2)
    threading.Thread(target=client.serve, kwargs=dict(poll_s=0.01),
                     daemon=True).start()
    host_t = pair.host()
    rng = np.random.default_rng(3)
    tcs = [TestConfig(i, "a", "s", space.sample(rng)) for i in range(10)]
    sched = DispatchScheduler([0], policy="eager", batch_size=len(tcs),
                              affinity="prefer", fingerprint_fn=jc.cache_key,
                              client_cache_size=64)
    for t in tcs:
        sched.submit(t)
    got = []
    deadline = time.monotonic() + 30.0
    while len(got) < len(tcs):
        for cid, chunk in sched.next_dispatches():
            host_t.push_many(cid, [t.to_wire() for t in chunk])
        msgs = host_t.pull_many(0.05)
        if msgs:
            sched.note_results()
        for m in msgs:
            sched.on_result(m)
            got.append(m)
        assert time.monotonic() < deadline, "client stalled"
    infos = [m["cache_info"] for m in got if "cache_info" in m]
    assert infos and infos[-1]["maxsize"] == 2
    # the optimistic dispatch marks were trimmed back to the client's
    # actual 2-slot LRU by the reply's cache_info sidecar
    shadow = sched.slots[0].shadow
    assert shadow.capacity == 2 and len(shadow) <= 2
    host_t.push(0, {"cmd": "stop"})


# ---------------------------------------------------------------------------
# SearchDriver staleness bound
# ---------------------------------------------------------------------------


class _BasisAlgo:
    """Records how many tells had been folded when each ask ran."""

    def __init__(self):
        self.n_told = 0
        self.ask_basis = []
        self._i = 0

    def ask(self, n):
        self.ask_basis.append(self.n_told)
        out = [{"i": self._i + k} for k in range(n)]
        self._i += n
        return out

    def tell(self, knobs, y):
        self.n_told += 1


def _wait(cond_fn, timeout_s=10.0):
    deadline = time.monotonic() + timeout_s
    while not cond_fn():
        if time.monotonic() > deadline:
            return False
        time.sleep(0.002)
    return True


def test_max_stale_tells_discards_and_recomputes():
    algo = _BasisAlgo()
    drv = SearchDriver(algo, mode="async", round_size=4, max_stale_tells=0)
    try:
        drv.note_demand(8)
        assert _wait(lambda: drv.ready() >= 8)
        for _ in range(3):
            drv.tell({"k": 1}, np.array([1.0, 2.0]))
        # the worker folds the tells (possibly across several rounds, each
        # finding the buffer staler than the bound), discards it, and
        # recomputes from fresh model state; the first discard alone drops
        # the whole 8-pick buffer
        assert _wait(lambda: (drv.stats()["tells_folded"] == 3
                              and drv.stats()["pending_tells"] == 0
                              and drv.ready() >= 1))
        assert drv.stats()["stale_dropped"] >= 8
        picks = drv.poll_ask(1, need=True)
        assert picks
        assert algo.ask_basis[-1] == 3        # recomputed after the fold
    finally:
        drv.close()


def test_unbounded_staleness_keeps_buffer():
    algo = _BasisAlgo()
    drv = SearchDriver(algo, mode="async", round_size=4)
    try:
        drv.note_demand(8)
        assert _wait(lambda: drv.ready() >= 8)
        for _ in range(5):
            drv.tell({"k": 1}, np.array([1.0, 2.0]))
        assert _wait(lambda: drv.stats()["pending_tells"] == 0)
        assert drv.stats()["stale_dropped"] == 0
        assert drv.ready() >= 8               # stale-tolerant by default
    finally:
        drv.close()


# ---------------------------------------------------------------------------
# PAL mean-only fast path
# ---------------------------------------------------------------------------


def _drive_pal(pal, n, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        c = pal.ask(1)[0]
        x = pal.space.encode(c)
        pal.tell(c, np.array([1.0 + x.sum(), 2.0 - x[0]])
                 + 0.01 * rng.random(2))


def test_pal_mean_only_skips_variance_for_classified_points():
    space = small_space(n_fps=8)              # 16 points: pools recycle fast
    pal = PAL(space, seed=0, n_init=4, pool_size=12, beta=0.5,
              gp_mode="incremental")
    _drive_pal(pal, 12)
    assert pal._ruled_out                      # classification happened
    assert pal.n_mean_only > 0                 # and re-entrants rode it
    assert len(pal.history_x) == 12            # picks stayed valid


def test_pal_mean_only_off_matches_shape():
    space = small_space(n_fps=8)
    pal = PAL(space, seed=0, n_init=4, pool_size=12, beta=0.5,
              gp_mode="incremental", mean_only=False)
    _drive_pal(pal, 12)
    assert pal.n_mean_only == 0 and not pal._ruled_out
    assert len(pal.history_x) == 12


# ---------------------------------------------------------------------------
# queued-chunk speculation (speculate_slow_mult)
# ---------------------------------------------------------------------------


def queued_sched(clk, **kw):
    kw.setdefault("policy", "pipelined")
    kw.setdefault("batch_size", 2)
    kw.setdefault("timeout_s", 10.0)
    kw.setdefault("speculate_slow_mult", 3.0)
    return DispatchScheduler([0, 1], fingerprint_fn=fp_of, clock=clk, **kw)


def _establish_ewmas(s, clk, slow_per_cfg=3.0, fast_per_cfg=0.1):
    """One chunk per client, answered at different speeds: client 0's EWMA
    lands at ``slow_per_cfg`` s/config, client 1's at ``fast_per_cfg``."""
    for i in range(4):
        s.submit(ftc(i, "A"))
    d = dict(s.next_dispatches())
    clk.advance(2 * fast_per_cfg)
    answer(s, 1, d[1])
    clk.advance(2 * slow_per_cfg - 2 * fast_per_cfg)
    answer(s, 0, d[0])
    assert s.slots[0].ewma_per_cfg_s == pytest.approx(slow_per_cfg)
    assert s.slots[1].ewma_per_cfg_s == pytest.approx(fast_per_cfg)


def _queue_on_slow(s):
    """Refill: client 0 (slow) gets a head chunk + a queued chunk [8, 9];
    client 1 gets a head chunk only, leaving it spare depth for a mirror."""
    for i in range(4, 10):
        s.submit(ftc(i, "A"))
    s.next_dispatches()
    queued = s.chunks[s.slots[0].chunks[1]]
    assert queued.started_at is None
    assert sorted(queued.awaiting) == [8, 9]
    return queued


def test_queued_chunk_mirrored_off_slow_client():
    clk = FakeClock()
    s = queued_sched(clk)
    _establish_ewmas(s, clk)
    _queue_on_slow(s)
    d = s.next_dispatches()                   # speculation pass
    assert len(d) == 1 and d[0][0] == 1       # mirrored to the fast client
    assert [t.config_id for t in d[0][1]] == [8, 9]
    assert s.n_spec_queued == 1 and s.n_speculated == 1
    assert s.next_dispatches() == []          # never mirrored twice
    st = s.stats()
    assert st["spec_queued"] == 1


def test_queued_mirror_win_counters_and_duplicates():
    clk = FakeClock()
    s = queued_sched(clk)
    _establish_ewmas(s, clk)
    _queue_on_slow(s)
    (c1, tcs), = s.next_dispatches()
    # fast client answers the mirror first (its own head, then the mirror)
    answer(s, 1, [ftc(6, "A"), ftc(7, "A")])
    answer(s, 1, tcs)
    assert s.n_spec_queued_wins_mirror == 1
    assert s.n_spec_cancelled == 1
    assert s.n_spec_wins_mirror == 0          # deadline-kind counter untouched
    # the cancelled primary left client 0's queue; its head is unaffected
    assert len(s.slots[0].chunks) == 1
    # the slow client's late answers are plain duplicates
    assert s.on_result(ok(8, 0)) is None
    assert s.on_result(ok(9, 0)) is None


def test_queued_primary_win_cancels_mirror():
    clk = FakeClock()
    s = queued_sched(clk)
    _establish_ewmas(s, clk)
    _queue_on_slow(s)
    s.next_dispatches()
    # the slow client powers through after all: head, then the queued chunk
    answer(s, 0, [ftc(4, "A"), ftc(5, "A")])
    answer(s, 0, [ftc(8, "A"), ftc(9, "A")])
    assert s.n_spec_queued_wins_primary == 1
    assert s.n_spec_cancelled == 1
    assert not s.slots[1].chunks or all(
        s.chunks[c].mirror_of is None for c in s.slots[1].chunks)


def test_no_queued_mirror_when_client_not_slow_enough():
    clk = FakeClock()
    s = queued_sched(clk)
    _establish_ewmas(s, clk, slow_per_cfg=0.25, fast_per_cfg=0.1)
    _queue_on_slow(s)
    assert s.next_dispatches() == []          # 0.25 < 3.0 * 0.1
    assert s.n_spec_queued == 0


def test_speculate_slow_mult_validation():
    with pytest.raises(ValueError):
        DispatchScheduler([0, 1], speculate_slow_mult=1.0)
    with pytest.raises(ValueError):
        DispatchScheduler([0, 1], speculate_slow_mult=0.5)


def test_resident_fingerprints_union_of_healthy_shadows():
    clk = FakeClock()
    s = affinity_sched(clk=clk)
    s.submit(ftc(0, "A"))
    s.submit(ftc(1, "B"))
    d = s.next_dispatches()
    for client, tcs in d:
        answer(s, client, tcs)
    assert s.resident_fingerprints() == {"A", "B"}
    owner_of_a = next(client for client, tcs in d
                      if any(t.knobs["sw"] == "A" for t in tcs))
    s.slots[owner_of_a].quarantined = True
    assert "A" not in s.resident_fingerprints()
