"""Fault tolerance end-to-end: kill the training driver mid-run, restart it,
and verify the final state is bit-identical to an uninterrupted run."""
import json
import os
import subprocess
import sys

import numpy as np

from tests.conftest import SRC

TRAIN = [sys.executable, "-m", "repro.launch.train", "--arch", "tinyllama-1.1b",
         "--reduced", "--batch", "4", "--seq", "32", "--save-every", "5",
         "--log-every", "100"]


def _run(args, expect_rc=0):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(TRAIN + args, env=env, capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == expect_rc, out.stdout + out.stderr
    return out.stdout


def _load_params(ckdir, step):
    d = os.path.join(ckdir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    return {k: np.load(os.path.join(d, m["file"]))
            for k, m in manifest["leaves"].items() if k.startswith("params/")}


def test_crash_restart_identical(tmp_path):
    straight = str(tmp_path / "straight")
    faulty = str(tmp_path / "faulty")

    # uninterrupted 15-step run
    _run(["--steps", "15", "--checkpoint-dir", straight])

    # crash at step 8 (rc 42), then restart to completion
    _run(["--steps", "15", "--checkpoint-dir", faulty, "--fault-at", "8"],
         expect_rc=42)
    out = _run(["--steps", "15", "--checkpoint-dir", faulty])
    assert "resumed from step 5" in out

    a = _load_params(straight, 15)
    b = _load_params(faulty, 15)
    assert a.keys() == b.keys() and len(a) > 0
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
