"""Roofline layer: HLO collective parsing, hardware model, traffic model."""
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch
from repro.models.model import BuildFlags
from repro.roofline.analysis import collective_wire_bytes, _shape_bytes
from repro.roofline.hw import HBM_LADDER, HwModel
from repro.roofline.traffic import analytic_hbm_bytes_per_device


def test_shape_bytes():
    assert _shape_bytes("f32[1024,1024]") == 4 * 1024 * 1024
    assert _shape_bytes("bf16[8,16]{1,0}") == 2 * 128
    assert _shape_bytes("(f32[4], bf16[2,2])") == 16 + 8
    assert _shape_bytes("pred[]") == 1


CRAFTED_HLO = """
ENTRY %main {
  %ag = f32[1024,1024]{1,0} all-gather(%x), channel_id=1, replica_groups=[1,4]<=[4], dimensions={1}
  %ar = bf16[512]{0} all-reduce(%y), replica_groups={{0,1},{2,3}}, to_apply=%add
  %rs = f32[256]{0} reduce-scatter(%z), replica_groups=[1,4]<=[4], dimensions={0}
  %cp = f32[128]{0} collective-permute(%w), source_target_pairs={{0,1},{1,2}}
  %ignored = f32[64]{0} add(%a, %b)
  %ags = (f32[64]{0}, f32[64]{0}) all-gather-start(%q), replica_groups=[1,4]<=[4]
}
"""


def test_collective_parsing_crafted():
    got = collective_wire_bytes(CRAFTED_HLO, 4)
    assert got["all-gather"] == pytest.approx(
        4 * 1024 * 1024 * 3 / 4       # main all-gather
        + (64 * 4 * 2) * 3 / 4)       # -start tuple counted once
    assert got["all-reduce"] == pytest.approx(2 * 512 * 2 * 1 / 2)  # group of 2
    assert got["reduce-scatter"] == pytest.approx(256 * 4 * 3)
    assert got["collective-permute"] == pytest.approx(128 * 4)
    assert "add" not in got


def test_hw_model_terms_and_ladders():
    hw = HwModel(n_chips=256)
    t = hw.roofline_terms(flops=197e12 * 256, hbm_bytes=0, collective_bytes=0)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["dominant"] == "compute_s"
    slow = HwModel(n_chips=256, hbm_scale=HBM_LADDER[0])
    t2 = slow.roofline_terms(flops=1, hbm_bytes=819e9 * 256, collective_bytes=0)
    assert t2["memory_s"] == pytest.approx(16.0)  # 1/16 EMC-analogue ladder


def test_power_monotone_in_clock():
    art_flops, art_bytes = 197e12 * 256 * 0.5, 819e9 * 256 * 0.1
    p = []
    for cs in (0.5, 0.75, 1.0):
        hw = HwModel(n_chips=256, clock_scale=cs)
        t = hw.roofline_terms(art_flops, art_bytes, 0)["step_time_s"]
        p.append(hw.power_w(art_flops, art_bytes, t))
    assert p[0] < p[1] < p[2]


def test_traffic_model_decode_dominated_by_weights_and_cache():
    arch = get_arch("glm4-9b")
    flags = BuildFlags()
    n_dev, dp, tp = 256, 16, 16
    got = analytic_hbm_bytes_per_device(arch, SHAPES["decode_32k"], flags,
                                        n_dev, dp, tp)
    w = arch.param_count() * 2 / tp
    cache = (128 * 32768 * 2 * arch.n_kv_heads * arch.d_head * 2 *
             arch.n_layers) / n_dev
    assert got == pytest.approx(w + cache, rel=0.35)


def test_traffic_model_train_scales_with_remat():
    arch = get_arch("tinyllama-1.1b")
    n_full = analytic_hbm_bytes_per_device(
        arch, SHAPES["train_4k"], BuildFlags(remat="full"), 256, 16, 16)
    n_none = analytic_hbm_bytes_per_device(
        arch, SHAPES["train_4k"], BuildFlags(remat="none"), 256, 16, 16)
    assert n_full > n_none


def test_traffic_model_moe_decode_touch_fraction():
    """long_500k (batch=1, top-1 of 128 experts) touches ~1/128 of expert
    weights; decode_32k (batch=128) touches most of them."""
    arch = get_arch("llama4-maverick-400b-a17b")
    flags = BuildFlags()
    b1 = analytic_hbm_bytes_per_device(arch, SHAPES["long_500k"], flags, 256, 16, 16)
    b128 = analytic_hbm_bytes_per_device(arch, SHAPES["decode_32k"], flags, 256, 16, 16)
    assert b1 < 0.2 * b128


def test_sliding_window_caps_decode_cache_traffic():
    g = get_arch("gemma3-27b")
    flags = BuildFlags()
    long = analytic_hbm_bytes_per_device(g, SHAPES["long_500k"], flags, 256, 16, 16)
    # hypothetical all-global variant: replace pattern with full attention
    import dataclasses
    from repro.configs.base import LayerSpec

    g_full = dataclasses.replace(g, pattern=(LayerSpec(mixer="attn"),),
                                 name="gemma-all-global")
    long_full = analytic_hbm_bytes_per_device(g_full, SHAPES["long_500k"],
                                              flags, 256, 16, 16)
    # weights dominate both totals; what the 5/6 windowed layers save is
    # *cache* traffic: n_local·(S - W)·2·hkv·dh·b per batch — check the delta
    n_local = sum(1 for sp in g.layer_specs() if sp.mixer == "attn_local")
    expect_delta = (n_local * (524288 - 1024) * 2 * g.n_kv_heads
                    * g.d_head * 2) / 256
    assert long < long_full
    assert abs((long_full - long) - expect_delta) < 0.4 * expect_delta
