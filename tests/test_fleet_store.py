"""Fleet-wide artifact store (PR 7): host-side query/serve/relay protocol,
designated-compiler serialization (exactly-F compiles), passive prefetch,
chunked blob streaming, client-side fetch/announce/serve-fetch plumbing,
scheduler free-rider placement, and end-to-end serve/relay explorations."""
import threading

import numpy as np
import pytest

from repro.core import (DispatchScheduler, FleetArtifactStore, JClient,
                        JConfig, JHost, ResultStore, TestConfig, transport)
from repro.core.transport import (ARTIFACT_CHUNK, ARTIFACT_FETCH,
                                  ARTIFACT_MISS, ARTIFACT_PUT,
                                  ARTIFACT_QUERY, chunk_blob)
from repro.core.space import DesignSpace, KIND_HW, KIND_SW, Knob
from repro.roofline.analysis import Artifact


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def toy_artifact(f=5e12, n_dev=256):
    return Artifact(flops_per_device=f, bytes_per_device=2e10,
                    wire_bytes_per_device=1e8, collectives={},
                    arg_bytes=10 ** 9, temp_bytes=10 ** 8,
                    output_bytes=10 ** 6, n_devices=n_dev)


def small_space(n_fps=4):
    return DesignSpace([
        Knob("clock_scale", (0.5, 1.0), KIND_HW),
        Knob("blk", tuple(range(n_fps)), KIND_SW),
    ])


def counting_build(jc):
    calls = []

    def build(tc):
        calls.append(jc.cache_key(tc))
        h = hash(jc.cache_key(tc)) % 7 + 1
        return toy_artifact(5e12 * h), {
            "decode_artifact": toy_artifact(1e11 * h),
            "n_decode_tokens": 10}

    return build, calls


def recorder():
    """A fake host push: collect (client_id, msg) pairs."""
    pushes = []
    return pushes, lambda cid, msg: pushes.append((cid, msg))


def put_frame(addr, cid=0, blob=b"engine-bytes", **extra):
    return {"cmd": ARTIFACT_PUT, "addr": addr, "fp": f"fp-{addr}",
            "client_id": cid, "blob": blob, **extra}


def query_frame(addr, cid, **extra):
    return {"cmd": ARTIFACT_QUERY, "addr": addr, "fp": f"fp-{addr}",
            "client_id": cid, **extra}


# ---------------------------------------------------------------------------
# FleetArtifactStore unit tests (transport-free, fake push + fake clock)
# ---------------------------------------------------------------------------


def test_first_query_assigns_compiler_later_queries_park():
    pushes, push = recorder()
    store = FleetArtifactStore("serve")
    store.on_message(query_frame("aa", 0), push)
    assert pushes == [(0, {"cmd": ARTIFACT_MISS, "addr": "aa"})]
    # second and third askers park behind the in-flight compile: no reply
    store.on_message(query_frame("aa", 1), push)
    store.on_message(query_frame("aa", 2), push)
    assert len(pushes) == 1
    assert store.n_misses == 1 and store.n_waits == 2
    # the compiler's PUT serves every waiter the blob
    store.on_message(put_frame("aa", cid=0), push)
    served = [(cid, m) for cid, m in pushes[1:]]
    assert sorted(cid for cid, _ in served) == [1, 2]
    assert all(m["cmd"] == ARTIFACT_PUT and m["blob"] == b"engine-bytes"
               for _, m in served)
    assert store.n_hits == 2


def test_serve_mode_caches_blob_for_later_queries():
    pushes, push = recorder()
    store = FleetArtifactStore("serve")
    store.on_message(put_frame("aa", cid=0), push)
    store.on_message(query_frame("aa", 3), push)
    assert pushes[-1][0] == 3
    assert pushes[-1][1]["cmd"] == ARTIFACT_PUT
    assert pushes[-1][1]["blob"] == b"engine-bytes"
    assert store.n_hits == 1 and store.n_misses == 0
    assert store.resident_fp("fp-aa")
    assert not store.resident_fp("fp-unknown")


def test_designated_compiler_requery_reconfirms_miss():
    pushes, push = recorder()
    store = FleetArtifactStore("serve")
    store.on_message(query_frame("aa", 0), push)
    store.on_message(query_frame("aa", 0), push)   # e.g. after timed-out wait
    assert pushes == [(0, {"cmd": ARTIFACT_MISS, "addr": "aa"})] * 2
    assert store.n_misses == 1                     # not a second assignment


def test_spec_query_never_assigns_compile_duty():
    pushes, push = recorder()
    store = FleetArtifactStore("serve")
    store.on_message(query_frame("aa", 0, spec=True), push)
    assert pushes == [(0, {"cmd": ARTIFACT_MISS, "addr": "aa",
                           "spec": True})]
    assert store.n_misses == 0 and not store._pending
    # the later *active* query still gets the assignment
    store.on_message(query_frame("aa", 1), push)
    assert pushes[-1] == (1, {"cmd": ARTIFACT_MISS, "addr": "aa"})
    assert store.n_misses == 1


def test_spec_query_joins_waiters_and_still_answers():
    pushes, push = recorder()
    store = FleetArtifactStore("serve")
    store.on_message(query_frame("aa", 0), push)              # compiler
    store.on_message(query_frame("aa", 1, spec=True), push)   # passive
    # answered immediately (spec MISS) *and* parked as waiter
    assert pushes[-1] == (1, {"cmd": ARTIFACT_MISS, "addr": "aa",
                              "spec": True})
    assert store._pending["aa"]["waiters"] == [1]
    store.on_message(put_frame("aa", cid=0), push)
    assert pushes[-1][0] == 1 and pushes[-1][1]["cmd"] == ARTIFACT_PUT


def test_relay_mode_round_trips_via_resident_peer():
    pushes, push = recorder()
    store = FleetArtifactStore("relay")
    # residency-only announcement: no blob retained by the host
    store.on_message({"cmd": ARTIFACT_PUT, "addr": "aa", "fp": "fp-aa",
                      "client_id": 0}, push)
    assert store.residency["aa"] == {0} and not store._blobs
    store.on_message(query_frame("aa", 1), push)
    assert pushes[-1][0] == 0
    assert pushes[-1][1]["cmd"] == ARTIFACT_FETCH
    assert store.n_relays == 1
    # the peer's blob PUT is forwarded to the waiter, still not retained
    store.on_message(put_frame("aa", cid=0), push)
    assert pushes[-1][0] == 1
    assert pushes[-1][1]["cmd"] == ARTIFACT_PUT
    assert pushes[-1][1]["blob"] == b"engine-bytes"
    assert not store._blobs


def test_relay_gone_fails_waiters_over_to_compile():
    pushes, push = recorder()
    store = FleetArtifactStore("relay")
    store.on_message({"cmd": ARTIFACT_PUT, "addr": "aa", "fp": "fp-aa",
                      "client_id": 0}, push)
    store.on_message(query_frame("aa", 1), push)
    store.on_message({"cmd": ARTIFACT_PUT, "addr": "aa", "client_id": 0,
                      "status": "gone"}, push)
    assert pushes[-1] == (1, {"cmd": ARTIFACT_MISS, "addr": "aa"})
    assert store.n_gone == 1
    assert store.residency["aa"] == set()          # claim dropped


def test_tick_expires_stale_assignment():
    clk = FakeClock()
    pushes, push = recorder()
    store = FleetArtifactStore("serve", pending_timeout_s=10.0, clock=clk)
    store.on_message(query_frame("aa", 0), push)
    store.on_message(query_frame("aa", 1), push)   # waiter
    clk.advance(5.0)
    store.tick(push)
    assert store.n_expired == 0                    # not yet
    clk.advance(6.0)
    store.tick(push)
    assert store.n_expired == 1 and not store._pending
    assert pushes[-1] == (1, {"cmd": ARTIFACT_MISS, "addr": "aa"})


def test_blob_cache_lru_eviction_by_bytes():
    pushes, push = recorder()
    store = FleetArtifactStore("serve", max_bytes=250)
    for i in range(4):
        store.on_message(put_frame(f"a{i}", cid=0, blob=bytes(100)), push)
    assert store.n_evictions == 2
    assert set(store._blobs) == {"a2", "a3"}       # oldest evicted first
    assert store._blob_bytes == 200
    # a served blob is LRU-touched: a0 is gone, a2 survives the next insert
    store.on_message(query_frame("a2", 1), push)
    store.on_message(put_frame("a4", cid=0, blob=bytes(100)), push)
    assert set(store._blobs) == {"a2", "a4"}


def test_chunked_put_reassembles_on_host():
    pushes, push = recorder()
    store = FleetArtifactStore("serve")
    blob = np.random.default_rng(0).bytes(2500)
    base = {"addr": "aa", "fp": "fp-aa", "client_id": 0}
    frames = chunk_blob(base, blob, 1000)
    assert len(frames) == 3
    assert all(f["cmd"] == ARTIFACT_CHUNK for f in frames)
    for f in frames:
        store.on_message(f, push)
    assert store._blobs["aa"] == blob
    store.on_message(query_frame("aa", 2), push)
    # served back out as a chunk run under the store's own chunk size
    small = FleetArtifactStore("serve", chunk_bytes=1000)
    small.on_message(put_frame("bb", cid=0, blob=blob), push)
    pushes.clear()
    small.on_message(query_frame("bb", 2), push)
    assert [m["cmd"] for _, m in pushes] == [ARTIFACT_CHUNK] * 3
    assert b"".join(m["blob"] for _, m in pushes) == blob


# ---------------------------------------------------------------------------
# JClient fleet tier (loopback, no serve thread: replies staged up front)
# ---------------------------------------------------------------------------


def fleet_client(pair, jc, build, cid=0, mode="serve", **kw):
    return JClient(jc, build, transport=pair.client(cid), client_id=cid,
                   fleet_mode=mode, fleet_timeout_s=2.0, **kw)


def staged_pair_and_key(n_fps=4):
    space = small_space(n_fps)
    jc = JConfig(space, n_chips=8)
    build, calls = counting_build(jc)
    rng = np.random.default_rng(0)
    tc = TestConfig(0, "a", "s", space.sample(rng))
    return transport.LoopbackPair(2), jc, build, calls, tc


def test_fleet_fetch_adopts_peer_blob():
    pair, jc, build, calls, tc = staged_pair_and_key()
    peer = fleet_client(pair, jc, build, cid=1)
    key = jc.cache_key(tc)
    built = build(tc)
    blob = peer._payload_blob(key, built)
    me = fleet_client(pair, jc, build, cid=0)
    # stage the host's reply before the (blocking) fetch
    pair.host().push(0, put_frame(me._addr(key), cid=1, blob=blob))
    got = me._fleet_fetch(key)
    assert got == built
    # the query went up the wire first
    q = pair.to_host.get(timeout=1.0)
    assert transport.decode_wire(q)["cmd"] == ARTIFACT_QUERY


def test_fleet_fetch_miss_makes_designated_compiler():
    pair, jc, build, calls, tc = staged_pair_and_key()
    me = fleet_client(pair, jc, build, cid=0)
    key = jc.cache_key(tc)
    addr = me._addr(key)
    host_t = pair.host()
    # a stale passive MISS must NOT be read as the assignment
    host_t.push(0, {"cmd": ARTIFACT_MISS, "addr": addr, "spec": True})
    host_t.push(0, {"cmd": ARTIFACT_MISS, "addr": addr})
    assert me._fleet_fetch(key) is None


def test_fetch_wait_serves_relayed_fetch_inline():
    """The deadlock killer: an ARTIFACT_FETCH arriving mid-wait is answered
    immediately, not backlogged behind the blocked fetch."""
    pair, jc, build, calls, tc = staged_pair_and_key()
    me = fleet_client(pair, jc, build, cid=0, mode="relay")
    held_tc = TestConfig(1, "a", "s", dict(tc.knobs, blk=(tc.knobs["blk"]
                                                          + 1) % 4))
    held_key = jc.cache_key(held_tc)
    me._addr_key[me._addr(held_key)] = held_key
    me._cache_insert(held_key, build(held_tc))
    want_key = jc.cache_key(tc)
    host_t = pair.host()
    host_t.push(0, {"cmd": ARTIFACT_FETCH, "addr": me._addr(held_key)})
    host_t.push(0, {"cmd": ARTIFACT_MISS, "addr": me._addr(want_key)})
    assert me._fleet_fetch(want_key) is None
    # host received: the QUERY, then the served blob for the relayed fetch
    frames = [transport.decode_wire(pair.to_host.get(timeout=1.0))
              for _ in range(2)]
    assert frames[0]["cmd"] == ARTIFACT_QUERY
    assert frames[1]["cmd"] == ARTIFACT_PUT
    assert frames[1]["addr"] == me._addr(held_key)
    assert isinstance(frames[1]["blob"], bytes)


def test_fetch_wait_backlogs_non_artifact_frames():
    pair, jc, build, calls, tc = staged_pair_and_key()
    me = fleet_client(pair, jc, build, cid=0)
    key = jc.cache_key(tc)
    host_t = pair.host()
    host_t.push(0, {"cmd": "whatever", "x": 1})
    host_t.push(0, {"cmd": ARTIFACT_MISS, "addr": me._addr(key)})
    assert me._fleet_fetch(key) is None
    assert me._rx_backlog == [{"cmd": "whatever", "x": 1}]
    assert me._pull(0.0) == {"cmd": "whatever", "x": 1}  # drained first


def test_serve_fetch_answers_gone_when_not_held():
    pair, jc, build, calls, tc = staged_pair_and_key()
    me = fleet_client(pair, jc, build, cid=0, mode="relay")
    me._serve_fetch("deadbeef")
    got = transport.decode_wire(pair.to_host.get(timeout=1.0))
    assert got["cmd"] == ARTIFACT_PUT and got["status"] == "gone"


def test_prefetch_adopts_blob_and_ignores_spec_miss():
    pair, jc, build, calls, tc = staged_pair_and_key()
    peer = fleet_client(pair, jc, build, cid=1)
    kA = jc.cache_key(tc)
    tcB = TestConfig(1, "a", "s", dict(tc.knobs, blk=(tc.knobs["blk"]
                                                      + 1) % 4))
    kB = jc.cache_key(tcB)
    built = build(tc)
    me = fleet_client(pair, jc, build, cid=0)
    host_t = pair.host()
    host_t.push(0, put_frame(me._addr(kA), cid=1,
                             blob=peer._payload_blob(kA, built)))
    host_t.push(0, {"cmd": ARTIFACT_MISS, "addr": me._addr(kB),
                    "spec": True})
    me._fleet_prefetch([kA, kB])
    assert me._cache[kA] == built
    info = me.cache_info()
    assert info["fleet_hits"] == 1
    # a spec MISS is not compile duty: no miss counted, nothing skipped
    assert info["fleet_misses"] == 0 and kB not in me._fleet_skip


def test_prefetch_active_miss_claims_compile_duty():
    pair, jc, build, calls, tc = staged_pair_and_key()
    me = fleet_client(pair, jc, build, cid=0)
    key = jc.cache_key(tc)
    pair.host().push(0, {"cmd": ARTIFACT_MISS, "addr": me._addr(key)})
    me._fleet_prefetch([key])
    assert key in me._fleet_skip
    assert me.cache_info()["fleet_misses"] == 1
    # _artifact honors the claim: builds without re-querying the fleet
    got = me._artifact(key, tc)
    assert me.n_compiled == 1 and key not in me._fleet_skip
    assert got == build(tc)


# ---------------------------------------------------------------------------
# scheduler: fleet-resident groups are free riders
# ---------------------------------------------------------------------------


def ftc(i, fp):
    return TestConfig(i, "a", "s", {"x": i, "sw": fp})


def test_fleet_resident_group_rides_past_fresh_budget():
    def dispatch_fps(fleet_fn):
        sched = DispatchScheduler([0], policy="eager", batch_size=6,
                                  affinity="strict", fingerprint_fn=lambda
                                  tc: tc.knobs["sw"],
                                  fleet_resident_fn=fleet_fn)
        for i, fp in enumerate(["A", "A", "B", "B", "C", "C"]):
            sched.submit(ftc(i, fp))
        dispatches = sched.next_dispatches()
        assert len(dispatches) == 1
        return sched, sorted({tc.knobs["sw"] for tc in dispatches[0][1]})

    # without the fleet: one fresh compile group per chunk
    sched0, fps0 = dispatch_fps(None)
    assert fps0 == ["A"]
    # with B fleet-resident it rides along for free beside the one fresh
    sched1, fps1 = dispatch_fps(lambda fp: fp == "B")
    assert fps1 == ["A", "B"]
    assert sched1.n_fleet_rides == 1
    assert sched1.stats()["fleet_rides"] == 1
    assert "fleet_rides" not in sched0.stats()


def test_fleet_resident_fn_errors_never_break_dispatch():
    sched = DispatchScheduler([0], policy="eager", batch_size=4,
                              affinity="strict",
                              fingerprint_fn=lambda tc: tc.knobs["sw"],
                              fleet_resident_fn=lambda fp: 1 / 0)
    sched.submit(ftc(0, "A"))
    out = sched.next_dispatches()
    assert len(out) == 1 and len(out[0][1]) == 1


# ---------------------------------------------------------------------------
# end to end: N clients x F fingerprints -> exactly F fleet compiles
# ---------------------------------------------------------------------------


class Replay:
    def __init__(self, ks):
        self._k = list(ks)

    def ask(self, n):
        out, self._k = self._k[:n], self._k[n:]
        return out

    def tell(self, knobs, y):
        pass


def run_fleet(knobs, space, jc, build, store, n_clients=4, pair=None,
              affinity="off"):
    pair = pair or transport.LoopbackPair(n_clients)
    clients = [JClient(jc, build, transport=pair.client(i), client_id=i,
                       cache_size=16, fleet_mode=store.mode,
                       fleet_timeout_s=10.0)
               for i in range(n_clients)]
    threads = [threading.Thread(target=c.serve, kwargs=dict(poll_s=0.005),
                                daemon=True) for c in clients]
    for t in threads:
        t.start()
    host = JHost(pair.host(), ResultStore(), timeout_s=60.0, poll_s=0.005)
    res = host.explore(Replay(knobs), "a", "s", len(knobs), batch_size=6,
                       dispatch="pipelined", affinity=affinity,
                       fingerprint_fn=jc.cache_key, fleet_store=store)
    for i in range(n_clients):
        host.transport.push(i, {"cmd": "stop"})
    for t in threads:
        t.join(timeout=10.0)
    return res, clients, pair


@pytest.mark.parametrize("mode", ["serve", "relay"])
def test_end_to_end_exactly_f_compiles(mode):
    space = small_space(n_fps=4)
    jc = JConfig(space, n_chips=8)
    build, calls = counting_build(jc)
    rng = np.random.default_rng(2)
    knobs = [space.sample(rng) for _ in range(24)]
    unique = len({jc.cache_key(TestConfig(0, "a", "s", k)) for k in knobs})
    store = FleetArtifactStore(mode)
    res, clients, _ = run_fleet(knobs, space, jc, build, store)
    assert sum(1 for r in res.records if r.status == "ok") >= len(knobs)
    # round-robin placement, but the store serialized every compile
    assert sum(c.n_compiled for c in clients) == unique
    assert len(calls) == unique
    assert store.stats()["fleet_hits"] > 0


def test_warm_peer_run_compiles_nothing():
    space = small_space(n_fps=4)
    jc = JConfig(space, n_chips=8)
    build, calls = counting_build(jc)
    rng = np.random.default_rng(3)
    knobs = [space.sample(rng) for _ in range(24)]
    unique = len({jc.cache_key(TestConfig(0, "a", "s", k)) for k in knobs})
    store = FleetArtifactStore("serve")
    run_fleet(knobs, space, jc, build, store)
    assert len(calls) == unique
    # brand-new clients (cold LRUs, no disk), same store: pure wire hits
    res, clients, _ = run_fleet(knobs, space, jc, build, store)
    assert sum(1 for r in res.records if r.status == "ok") >= len(knobs)
    assert sum(c.n_compiled for c in clients) == 0
    assert len(calls) == unique                   # no new builds at all
    assert sum(c.cache_info()["fleet_hits"] for c in clients) > 0
