"""Continuous batching: SlotServer must reproduce Engine's greedy outputs for
every request regardless of arrival order/slot assignment, including the SSM
family (state rows swapped wholesale on slot reuse)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import BuildFlags, Model
from repro.serve import Engine
from repro.serve.kv_cache import SlotServer


def _reference(model, params, prompt, n_new, max_len):
    eng = Engine(model, params, max_len=max_len, donate=False)
    res = eng.generate({"tokens": jnp.asarray(prompt[None, :])}, n_new)
    return res.tokens[0].tolist()


@pytest.mark.parametrize("arch_name", ["tinyllama-1.1b", "mamba2-780m"])
def test_slot_server_matches_engine(arch_name):
    arch = reduced(get_arch(arch_name))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    max_len = 48

    prompts = [rng.integers(0, arch.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]
    new_counts = [6, 4, 8]

    srv = SlotServer(model, params, n_slots=2, max_len=max_len)
    for i, (p, n) in enumerate(zip(prompts, new_counts)):
        srv.submit(i, p, n)
    finished = srv.run()
    assert len(finished) == 3
    got = {r.rid: r.out for r in finished}

    for i, (p, n) in enumerate(zip(prompts, new_counts)):
        want = _reference(model, params, p, n, max_len)
        assert got[i] == want, f"req {i}: {got[i]} != {want}"


def test_slot_reuse_after_finish():
    """More requests than slots: freed slots must serve later requests."""
    arch = reduced(get_arch("tinyllama-1.1b"))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    params = model.init(jax.random.key(1))
    srv = SlotServer(model, params, n_slots=2, max_len=32)
    rng = np.random.default_rng(1)
    for i in range(5):
        srv.submit(i, rng.integers(0, arch.vocab_size, size=4).astype(np.int32), 3)
    finished = srv.run()
    assert sorted(r.rid for r in finished) == [0, 1, 2, 3, 4]
    assert all(len(r.out) == 3 for r in finished)
