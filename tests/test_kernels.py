"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container has no hypothesis
    from _propcheck import given, settings, st

from repro.kernels import ops, ref


def _attn_inputs(key, b, s, h, hkv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("s,h,hkv,d,blk", [
    (64, 4, 4, 32, 16),      # MHA
    (128, 8, 2, 64, 32),     # GQA 4:1
    (96, 6, 1, 32, 32),      # MQA, non-block-multiple seq
    (128, 4, 4, 128, 64),    # MXU-width head dim
])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, hkv, d, blk, window, dtype):
    q, k, v = _attn_inputs(jax.random.key(0), 2, s, h, hkv, d, dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=blk, block_kv=blk)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(8, 80),
    rep=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 32]),
    window=st.sampled_from([0, 16]),
)
def test_flash_attention_property(s, rep, hkv, d, window):
    q, k, v = _attn_inputs(jax.random.key(3), 1, s, hkv * rep, hkv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=16, block_kv=16)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def _ssd_inputs(key, b, s, h, p, n, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(jnp.float32)
    a_log = (-dt * jax.nn.sigmoid(jax.random.normal(ks[2], (b, s, h)))).astype(jnp.float32)
    bb = (0.4 * jax.random.normal(ks[3], (b, s, n))).astype(dtype)
    cc = (0.4 * jax.random.normal(ks[0], (b, s, n))).astype(dtype)
    return x, a_log, bb, cc, dt


@pytest.mark.parametrize("s,h,p,n,chunk", [
    (64, 2, 16, 16, 16),
    (96, 4, 32, 32, 32),     # non-power-of-two chunks count
    (40, 1, 16, 64, 16),     # padding path (40 % 16 != 0)
])
def test_ssd_scan_sweep(s, h, p, n, chunk):
    x, a_log, bb, cc, dt = _ssd_inputs(jax.random.key(1), 2, s, h, p, n)
    y, state = ops.ssd_scan(x, a_log, bb, cc, dt, chunk=chunk)
    y_ref, state_ref = ref.ssd_ref(x, a_log, bb, cc, dt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref), atol=1e-4, rtol=1e-4)


def test_ssd_scan_matches_model_chunked_path():
    """models.mamba2.ssd_chunked (jnp) and the Pallas kernel agree."""
    from repro.models.mamba2 import ssd_chunked

    x, a_log, bb, cc, dt = _ssd_inputs(jax.random.key(2), 1, 64, 2, 16, 32)
    y1, s1 = ssd_chunked(x, a_log, bb, cc, dt, chunk=16, impl="jnp")
    y2, s2 = ops.ssd_scan(x, a_log, bb, cc, dt, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(1, 200), e=st.sampled_from([4, 16, 64]),
       k=st.integers(1, 4))
def test_topk_gating_property(t, e, k):
    k = min(k, e)
    logits = jax.random.normal(jax.random.key(t), (t, e))
    p, ids = ops.topk_gating(logits, k, block_t=64)
    p_ref, ids_ref = ref.topk_gating_ref(logits, k)
    np.testing.assert_allclose(np.asarray(p), np.asarray(p_ref), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_ref))


def test_flash_vmem_budget():
    assert ops.flash_attention_vmem_bytes(256, 256, 128) < ops.VMEM_BUDGET_BYTES
    assert ops.flash_attention_vmem_bytes(512, 512, 128) < ops.VMEM_BUDGET_BYTES
