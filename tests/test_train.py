"""Training substrate: optimizers, grad accumulation, compression, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import BuildFlags, Model
from repro.parallel.compress import ef_compress_tree, ef_init
from repro.train import (TrainStepConfig, adafactor, adamw, cosine_schedule,
                         init_train_state, make_train_step)
from repro.train.optimizer import clip_by_global_norm, global_norm


def _setup(microbatch=1, grad_compress=False, optimizer="adamw"):
    arch = reduced(get_arch("tinyllama-1.1b"))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    sched = cosine_schedule(1e-3, 5, 100)
    opt = adafactor(sched) if optimizer == "adafactor" else adamw(sched)
    tsc = TrainStepConfig(microbatch=microbatch, grad_compress=grad_compress)
    state = init_train_state(model, opt, jax.random.key(0), tsc)
    step = jax.jit(make_train_step(model, opt, tsc))
    data = SyntheticLM(arch, DataConfig(batch=8, seq_len=32, seed=3))
    return model, state, step, data


@pytest.mark.parametrize("optimizer", ["adamw", "adafactor"])
def test_loss_decreases(optimizer):
    _, state, step, data = _setup(optimizer=optimizer)
    losses = []
    for i in range(10):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_microbatch_equivalence():
    """Grad accumulation (microbatch=2/4) matches the single-shot gradient."""
    _, state1, step1, data = _setup(microbatch=1)
    batch = jax.tree.map(jnp.asarray, data.batch(0))
    _, state2, step2, _ = _setup(microbatch=2)
    _, state4, step4, _ = _setup(microbatch=4)
    s1, m1 = step1(state1, batch)
    s2, m2 = step2(state2, batch)
    s4, m4 = step4(state4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(55)) < float(lr(20))


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the threshold: untouched
    same, _ = clip_by_global_norm(tree, 100.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0)


def test_ef_compression_error_feedback():
    """Quantisation error is carried, not lost: sum of compressed grads over
    many steps converges to the sum of true grads (EF-SGD property)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros((64,))
    comp_sum = np.zeros((64,))
    ef = ef_init({"g": jnp.zeros((64,))})
    for _ in range(50):
        g = {"g": jnp.asarray(rng.standard_normal(64) * 0.1)}
        true_sum += np.asarray(g["g"])
        cg, ef = ef_compress_tree(g, ef)
        comp_sum += np.asarray(cg["g"])
    resid = np.abs(true_sum - comp_sum).max()
    # residual bounded by one step's quantisation error, not accumulated
    assert resid < 0.05


def test_grad_compress_training_converges():
    _, state, step, data = _setup(grad_compress=True)
    losses = []
    for i in range(10):
        state, m = step(state, jax.tree.map(jnp.asarray, data.batch(i)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_train_state_shapes_no_alloc():
    from repro.train import train_state_shapes

    arch = reduced(get_arch("deepseek-moe-16b"))
    model = Model(arch, BuildFlags(dtype="float32", sp=False))
    opt = adamw(cosine_schedule(1e-3, 5, 100))
    shapes = train_state_shapes(model, opt)
    assert all(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree.leaves(shapes))
