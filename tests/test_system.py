"""End-to-end behaviour of the paper's system: a real (reduced) model workload
explored by JHost/JClient over loopback, reproducing the paper's experiment
shape — inverse time/power correlation, a Pareto frontier, and the detached
lowest-EMC-analogue cluster (§IV)."""
import threading

import numpy as np
import pytest

from repro.core import (JClient, JConfig, JHost, RandomSearch, ResultStore,
                        transport)
from repro.core.space import DesignSpace, Knob, KIND_HW, KIND_SW
from repro.roofline.hw import CLOCK_LADDER, HBM_LADDER, ICI_LADDER


def _generation_space():
    return DesignSpace([
        Knob("clock_scale", CLOCK_LADDER, KIND_HW),
        Knob("hbm_scale", HBM_LADDER, KIND_HW),
        Knob("ici_scale", ICI_LADDER, KIND_HW),
        Knob("dp_degree", (1,), KIND_SW),
        Knob("attn_block_q", (16, 32), KIND_SW),
    ])


@pytest.fixture(scope="module")
def explored_store():
    """Run one real exploration (reduced llama2, 60 samples) shared by tests."""
    import jax

    from repro.configs import get_arch, reduced
    from repro.launch.build import build_generation
    from repro.launch.mesh import make_host_mesh
    from repro.models import BuildFlags
    from repro.roofline.analysis import summarize
    from repro.roofline.traffic import analytic_hbm_bytes_per_device
    from repro.configs.base import ShapeConfig

    arch = reduced(get_arch("llama2-7b"))
    mesh = make_host_mesh()
    space = _generation_space()
    jc = JConfig(space, n_chips=1)

    def build(tc):
        flags = jc.build_flags(tc.knobs)
        pre_cell, dec_cell = build_generation(arch, mesh, flags, batch=1,
                                              prompt_len=16, max_len=48)
        pre = summarize(pre_cell.compiled, 1)
        dec = summarize(dec_cell.compiled, 1)
        pre.hbm_est_per_device = analytic_hbm_bytes_per_device(
            arch, ShapeConfig("p", "prefill", 16, 1), flags, 1, 1, 1)
        dec.hbm_est_per_device = analytic_hbm_bytes_per_device(
            arch, ShapeConfig("d", "decode", 48, 1), flags, 1, 1, 1)
        return pre, {"decode_artifact": dec, "n_decode_tokens": 32}

    pair = transport.LoopbackPair(2)
    clients = [JClient(jc, build, transport=pair.client(i), client_id=i)
               for i in range(2)]
    for c in clients:
        threading.Thread(target=c.serve,
                         kwargs=dict(poll_s=0.02, idle_limit_s=None),
                         daemon=True).start()
    host = JHost(pair.host(), ResultStore(), timeout_s=300.0, poll_s=0.02)
    algo = RandomSearch(space, seed=0)
    host.explore(algo, "llama2-7b-reduced", "generate", 60)
    host.stop_clients()
    assert sum(c.n_compiled for c in clients) <= 4  # 2 sw variants × 2 clients
    return host.store


def test_exploration_completes(explored_store):
    assert len(explored_store.ok_records()) == 60


def test_inverse_time_power_correlation(explored_store):
    """Paper §IV: 'power consumption and inference latency are inversely
    correlated as expected'."""
    pts = explored_store.objective_matrix(["time_s", "power_w"])
    r = np.corrcoef(pts[:, 0], pts[:, 1])[0, 1]
    assert r < -0.1, f"expected inverse correlation, got r={r:.2f}"


def test_pareto_frontier_emerges(explored_store):
    front = explored_store.pareto_front(["time_s", "power_w"])
    assert 2 <= len(front) < 60


def test_lowest_emc_analogue_cluster(explored_store):
    """Paper §IV: the lowest EMC step detaches a cluster in time — our
    hbm_scale=1/16 ladder step must reproduce the cut-off effect: every
    config in the slowest cluster uses the lowest step, and the gap between
    clusters exceeds the in-cluster spread."""
    recs = explored_store.ok_records()
    times = np.array([r.metrics["time_s"] for r in recs])
    low = np.array([r.knobs["hbm_scale"] == HBM_LADDER[0] for r in recs])
    assert low.any() and (~low).any()
    assert times[low].min() > times[~low].max(), "no detached cluster"
    gap = times[low].min() - times[~low].max()
    assert gap > 0.5 * (times[~low].max() - times[~low].min())


def test_csv_export(explored_store, tmp_path):
    p = str(tmp_path / "explored.csv")
    explored_store.to_csv(p)
    with open(p) as f:
        header = f.readline()
    assert "knob.hbm_scale" in header and "metric.time_s" in header
