"""JAX GP fast path (gp_mode="jax"): numerical equivalence to the numpy
reference across doubling boundaries, float64 regression (no silent float32
and no global x64 leak), the fused EHVI device sweep, subset-of-data
inducing points (engagement + error bound), degenerate-append fallback,
pick-sequence equality through BayesOpt/PAL, and the hyperparameter refresh
schedule riding the device buffers."""
import numpy as np
import pytest

gp_jax = pytest.importorskip("repro.core.search.gp_jax")

from repro.core.search.bayesopt import (BayesOpt, GP, IncrementalGP, PAL,
                                        ehvi_improvements)
from repro.core.search.gp_jax import JaxIncrementalGP
from repro.core.space import tpu_pod_space


def _toy_objectives(space, knobs):
    x = space.encode(knobs)
    time = 2.0 - 1.2 * x[0] + 0.4 * x[1] + 0.1 * np.sin(7 * x.sum())
    power = 0.5 + 1.5 * x[0] ** 2 + 0.2 * x[2]
    return np.array([time, power])


# ---------------------------------------------------------------------------
# numerical equivalence to the numpy IncrementalGP
# ---------------------------------------------------------------------------


def test_jax_matches_numpy_across_doubling_boundaries():
    """Mixed append block sizes crossing the capacity doublings (16, 32)
    must produce posteriors equal to the numpy rank-append path at float64
    round-off — single-target and multi-target."""
    rng = np.random.default_rng(0)
    ref = IncrementalGP()
    jgp = JaxIncrementalGP()
    xs = np.zeros((0, 5))
    for step in (1, 1, 3, 1, 10, 1, 2, 17):
        xn = rng.random((step, 5))
        xs = np.vstack([xs, xn])
        ref.observe(xn)
        jgp.observe(xn)
        assert len(jgp) == len(xs)
    y = rng.random(len(xs))
    Y = rng.random((len(xs), 2))
    q = rng.random((9, 5))
    mu_r, sig_r = ref.fit_y(y).predict(q)
    mu_j, sig_j = jgp.fit_y(y).predict(q)
    np.testing.assert_allclose(mu_j, mu_r, atol=1e-10)
    np.testing.assert_allclose(sig_j, sig_r, atol=1e-10)
    mu_r, sig_r = ref.fit_y_multi(Y).predict_multi(q)
    mu_j, sig_j = jgp.fit_y_multi(Y).predict_multi(q)
    np.testing.assert_allclose(mu_j, mu_r, atol=1e-10)
    np.testing.assert_allclose(sig_j, sig_r, atol=1e-10)
    np.testing.assert_allclose(jgp.predict_mean_multi(q),
                               ref.predict_mean_multi(q), atol=1e-10)


def test_float64_end_to_end_no_global_leak():
    """The device path must run in true float64 — a silently-float32 path
    cannot hit 1e-12 against the numpy reference — while jax's global
    default dtype stays float32 outside the scoped enable_x64 blocks."""
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    xs = rng.random((40, 4))
    y = rng.random(40)
    q = rng.random((8, 4))
    jgp = JaxIncrementalGP().fit_x(xs).fit_y(y)
    assert jgp._xb.dtype == jnp.float64
    assert jgp._lb.dtype == jnp.float64
    mu_r, sig_r = IncrementalGP().fit_x(xs).fit_y(y).predict(q)
    mu_j, sig_j = jgp.predict(q)
    np.testing.assert_allclose(mu_j, mu_r, atol=1e-12)
    np.testing.assert_allclose(sig_j, sig_r, atol=1e-12)
    assert mu_j.dtype == np.float64
    # scoping regression: enable_x64 must not leak into the process default
    assert jnp.zeros(1).dtype == jnp.float32


def test_fused_ehvi_matches_numpy_staircase():
    rng = np.random.default_rng(2)
    xs = rng.random((30, 4))
    Y = rng.random((30, 2))
    pool = rng.random((25, 4))
    ref_pt = Y.max(0) * 1.1 + 1e-9
    ref = IncrementalGP().fit_x(xs).fit_y_multi(Y)
    mus = ref.predict_mean_multi(pool)
    want = ehvi_improvements(Y, ref_pt, mus)
    jgp = JaxIncrementalGP().fit_x(xs)
    jgp.fit_y_multi(Y)
    got = jgp.score_ehvi(pool, Y, ref_pt)
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_degenerate_append_triggers_nan_flag_fallback():
    """With zero noise an exact duplicate makes the append's Schur
    complement numerically non-PD.  ``jnp.linalg.cholesky`` returns NaN
    instead of raising (unlike numpy's LinAlgError), so the append jit
    reports a finiteness flag and the masked full refactor engages."""
    rng = np.random.default_rng(3)
    xs = rng.random((12, 3))
    jgp = JaxIncrementalGP(noise=0.0).fit_x(xs)
    before = jgp.n_refactors
    jgp.observe(np.vstack([xs[3][None], xs[3][None]]))
    assert jgp.n_refactors == before + 1
    assert len(jgp) == 14                     # the data still landed


def test_masked_refactor_matches_numpy_factorisation():
    """The fallback payload: a full masked refactor over the zero-padded
    device buffers must reproduce the numpy factorisation exactly."""
    rng = np.random.default_rng(6)
    xs = rng.random((20, 3))
    jgp = JaxIncrementalGP().fit_x(xs)
    jgp._refactor()                           # force the fallback path
    y = rng.random(20)
    q = rng.random((6, 3))
    mu_j, sig_j = jgp.fit_y(y).predict(q)
    mu_r, sig_r = IncrementalGP().fit_x(xs).fit_y(y).predict(q)
    np.testing.assert_allclose(mu_j, mu_r, atol=1e-10)
    np.testing.assert_allclose(sig_j, sig_r, atol=1e-10)


# ---------------------------------------------------------------------------
# inducing points (subset-of-data)
# ---------------------------------------------------------------------------


def test_inducing_points_engage_and_stay_bounded():
    rng = np.random.default_rng(4)
    jgp = JaxIncrementalGP(inducing_threshold=64)
    xs = rng.random((300, 3))
    for i in range(0, 300, 25):
        jgp.observe(xs[i:i + 25])
    assert jgp.n_total == 300
    # active set stays within the thinning band around the threshold
    assert len(jgp) <= int(64 * jgp.inducing_overflow)
    assert jgp.n_thins > 0
    s = jgp.stats()
    assert s["n_active"] == len(jgp) and s["n_total"] == 300


def test_inducing_error_bounded_on_smooth_function():
    """SoD on a smooth target: the thinned posterior tracks the function to
    a loose tolerance (far tighter than the function's range)."""
    rng = np.random.default_rng(5)
    xs = rng.random((300, 2))

    def f(x):
        return np.sin(3 * x[:, 0]) + 0.5 * np.cos(2 * x[:, 1])

    jgp = JaxIncrementalGP(inducing_threshold=64).fit_x(xs).fit_y(f(xs))
    q = rng.random((50, 2))
    mu, _ = jgp.predict(q)
    rmse = float(np.sqrt(np.mean((mu - f(q)) ** 2)))
    assert rmse < 0.15                     # function range is ~3.0


# ---------------------------------------------------------------------------
# pick-sequence equality through the searchers
# ---------------------------------------------------------------------------


def test_bayesopt_jax_picks_match_incremental():
    space = tpu_pod_space(n_chips=256)
    seqs = {}
    for mode in ("incremental", "jax"):
        algo = BayesOpt(space, seed=3, n_init=6, pool_size=64,
                        strategy="ehvi", gp_mode=mode)
        seq = []
        for _ in range(30):
            c = algo.ask(1)[0]
            algo.tell(c, _toy_objectives(space, c))
            seq.append(c)
        seqs[mode] = seq
    assert seqs["jax"] == seqs["incremental"]


def test_pal_jax_picks_match_incremental():
    space = tpu_pod_space(n_chips=256)
    seqs = {}
    for mode in ("incremental", "jax"):
        algo = PAL(space, seed=3, n_init=6, pool_size=64, gp_mode=mode)
        seq = []
        for _ in range(20):
            c = algo.ask(1)[0]
            algo.tell(c, _toy_objectives(space, c))
            seq.append(c)
        seqs[mode] = seq
    assert seqs["jax"] == seqs["incremental"]


def test_jax_hyper_refresh_retunes_lengthscale():
    """On a purely linear target the log-ML prefers a larger lengthscale
    than the 0.3 default — the schedule must both fire and actually move
    the hyperparameter on the device buffers."""
    space = tpu_pod_space(n_chips=256)
    algo = BayesOpt(space, seed=3, n_init=6, pool_size=64,
                    strategy="ehvi", gp_mode="jax", hyper_refresh_every=10)
    for _ in range(30):
        c = algo.ask(1)[0]
        x = space.encode(c)
        algo.tell(c, np.array([x[0] + 0.5 * x[1], 1.0 - x[0] + 0.3 * x[2]]))
    assert algo.n_hyper_refreshes >= 2
    assert algo._gp.ls > 0.3
