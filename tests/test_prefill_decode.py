"""Prefill↔decode equivalence: running prefill over S tokens gives the same
last-token logits as prefill over S-1 + one decode step — for every mixer
family (attention, sliding-window, SSD, MoE, hybrid, VLM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import BuildFlags, Model

FAMS = ["tinyllama-1.1b", "gemma3-27b", "jamba-v0.1-52b", "mamba2-780m",
        "deepseek-moe-16b", "internvl2-2b", "musicgen-medium"]


def _pad_caches(caches, old_s):
    def pad(c):
        if c.ndim >= 3 and c.shape[-3] == old_s:
            w = [(0, 0)] * c.ndim
            w[-3] = (0, 1)
            return jnp.pad(c, w)
        return c
    return jax.tree.map(pad, caches)


@pytest.mark.parametrize("name", FAMS)
def test_prefill_vs_decode(name):
    arch = reduced(get_arch(name))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    params = model.init(jax.random.key(1))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, arch.vocab_size)
    extra = {}
    n_text = s
    if arch.frontend == "vision":
        f = arch.n_frontend_tokens
        extra["image_embeds"] = jax.random.normal(
            jax.random.key(3), (b, f, arch.d_model))
        n_text = s - f
    if arch.frontend == "audio":
        pytest.skip("audio frontend has no token-decode prefix semantics")

    full, _ = model.prefill(params, {**extra, "tokens": toks[:, :n_text]})
    pre, caches = model.prefill(params, {**extra, "tokens": toks[:, :n_text - 1]})
    caches = _pad_caches(caches, s - 1)
    dec, _ = model.decode_step(params, toks[:, n_text - 1:n_text], caches, s - 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               atol=5e-5, rtol=5e-5)


def test_multi_step_decode_matches_prefill():
    """Four consecutive decode steps equal one long prefill (tinyllama)."""
    arch = reduced(get_arch("tinyllama-1.1b"))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    params = model.init(jax.random.key(5))
    b, s0, extra_steps = 1, 8, 4
    s = s0 + extra_steps
    toks = jax.random.randint(jax.random.key(6), (b, s), 0, arch.vocab_size)

    _, caches = model.prefill(params, {"tokens": toks[:, :s0]})
    # grow caches to full length
    def grow(c):
        if c.ndim >= 3 and c.shape[-3] == s0:
            w = [(0, 0)] * c.ndim
            w[-3] = (0, extra_steps)
            return jnp.pad(c, w)
        return c
    caches = jax.tree.map(grow, caches)
    for i in range(extra_steps):
        logits, caches = model.decode_step(params, toks[:, s0 + i:s0 + i + 1],
                                           caches, s0 + i)
    full, _ = model.prefill(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(full), np.asarray(logits),
                               atol=5e-5, rtol=5e-5)
