"""Per-arch smoke: reduced same-family config, one forward + one train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_arch, reduced
from repro.data import DataConfig, SyntheticLM
from repro.models import BuildFlags, Model
from repro.train import TrainStepConfig, adamw, cosine_schedule, init_train_state, make_train_step

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


@pytest.mark.parametrize("name", ALL)
def test_forward_and_train_step(name):
    arch = reduced(get_arch(name))
    model = Model(arch, BuildFlags(dtype="float32", remat="selective", sp=False))
    data = SyntheticLM(arch, DataConfig(batch=2, seq_len=24, seed=0))
    batch = jax.tree.map(jnp.asarray, data.batch(0))

    params = model.init(jax.random.key(0))
    loss, metrics = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"

    opt = adamw(cosine_schedule(1e-3, 2, 10))
    state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(make_train_step(model, opt))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    assert int(state["step"]) == 1
    # params actually changed and stayed finite
    for p in jax.tree.leaves(state["params"]):
        assert np.all(np.isfinite(np.asarray(p, dtype=np.float32)))


@pytest.mark.parametrize("name", ALL)
def test_prefill_shapes(name):
    arch = reduced(get_arch(name))
    model = Model(arch, BuildFlags(dtype="float32", remat="none", sp=False))
    params = model.init(jax.random.key(1))
    b, s = 2, 16
    batch = {}
    if arch.frontend == "vision":
        f = arch.n_frontend_tokens
        batch["image_embeds"] = jnp.zeros((b, f, arch.d_model))
        batch["tokens"] = jnp.zeros((b, s - f), jnp.int32)
    elif arch.frontend == "audio":
        batch["frame_embeds"] = jnp.zeros((b, s, arch.d_model))
    else:
        batch["tokens"] = jnp.zeros((b, s), jnp.int32)
    logits, caches = model.prefill(params, batch)
    assert logits.shape == (b, arch.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert caches  # non-empty cache pytree


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned hyperparameters."""
    a = get_arch("deepseek-moe-16b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads) == (28, 2048, 16, 16)
    assert (a.n_experts, a.moe_top_k, a.n_shared_experts) == (64, 6, 2)
    assert a.vocab_size == 102400 and a.moe_d_ff == 1408
    a = get_arch("llama4-maverick-400b-a17b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads) == (48, 5120, 40, 8)
    assert (a.n_experts, a.moe_top_k, a.vocab_size) == (128, 1, 202048)
    a = get_arch("glm4-9b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff) == (40, 4096, 32, 2, 13696)
    a = get_arch("tinyllama-1.1b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff) == (22, 2048, 32, 4, 5632)
    a = get_arch("gemma3-27b")
    assert (a.n_layers, a.d_model, a.vocab_size) == (62, 5376, 262144)
    assert len(a.pattern) == 6  # 5 local : 1 global
    a = get_arch("yi-9b")
    assert (a.n_layers, a.d_model, a.n_kv_heads, a.vocab_size) == (48, 4096, 4, 64000)
    a = get_arch("jamba-v0.1-52b")
    assert (a.n_layers, a.n_experts, a.moe_top_k) == (32, 16, 2)
    mixers = [s.mixer for s in a.layer_specs()]
    assert mixers.count("attn") == 4 and mixers.count("mamba") == 28  # 1:7
    a = get_arch("musicgen-medium")
    assert (a.n_layers, a.d_model, a.n_heads, a.vocab_size) == (48, 1536, 24, 2048)
    a = get_arch("internvl2-2b")
    assert (a.n_layers, a.d_model, a.vocab_size) == (24, 2048, 92553)
    a = get_arch("mamba2-780m")
    assert (a.n_layers, a.d_model, a.ssm_state, a.vocab_size) == (48, 1536, 128, 50280)
    assert a.n_heads == 0 and a.d_ff == 0


def test_param_counts_plausible():
    """Analytic param counts land near the advertised sizes."""
    import math

    expect = {
        "deepseek-moe-16b": 16e9, "glm4-9b": 9e9, "tinyllama-1.1b": 1.1e9,
        "gemma3-27b": 27e9, "yi-9b": 9e9, "jamba-v0.1-52b": 52e9,
        "mamba2-780m": 0.78e9, "llama2-7b": 7e9,
        "llama4-maverick-400b-a17b": 400e9,
    }
    for name, n in expect.items():
        got = get_arch(name).param_count()
        assert 0.5 < got / n < 1.6, f"{name}: {got:.3g} vs {n:.3g}"
    # MoE active counts are much smaller than totals
    a = get_arch("llama4-maverick-400b-a17b")
    assert a.active_param_count() < 0.1 * a.param_count()
